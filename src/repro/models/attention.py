"""Attention: GQA/MQA, full (blockwise online-softmax), local
(sliding-window / chunked, exact 2-chunk formulation), and decode-over-cache.

Three execution paths, chosen by layer kind and phase:

  * full train/prefill — lax.scan over KV blocks with online softmax
    (flash-attention at the JAX level; the Pallas kernel in
    kernels/flash_attn.py is the TPU-target twin, validated vs the same
    oracle). O(S·block) memory instead of O(S²).
  * local train/prefill — seq reshaped to (chunks, W); each q-chunk attends
    to [previous ‖ current] chunk. Exact for sliding windows ≤ W (a token
    looks back < W ⇒ within the two chunks) and for llama4-style chunked
    attention (current chunk only). O(S·W) compute — this is what makes
    gemma3/llama4 long-context shapes sub-quadratic.
  * decode — single-token einsum over the (possibly ring-buffered) cache.

GQA: K/V are stored with HK heads and broadcast to H = HK·g query heads by
jnp.repeat at use; under head sharding the repeat of a replicated KV tensor
partitions to a local slice (no collective, no HBM copy of the full tensor).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distrib.sharding import constrain
from .common import Initializer, apply_mrope, apply_rope

F32 = jnp.float32
NEG = jnp.asarray(-1e30, F32)


def init_attention(ini: Initializer, cfg) -> dict:
    d, h, hk, dh = cfg.d_model, cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    std_o = 0.02 / (2 * cfg.num_layers) ** 0.5
    return {
        "wq": ini.normal((d, h, dh), ("fsdp", "heads", None)),
        "wk": ini.normal((d, hk, dh), ("fsdp", "kv_heads", None)),
        "wv": ini.normal((d, hk, dh), ("fsdp", "kv_heads", None)),
        "wo": ini.normal((h, dh, d), ("heads", None, "fsdp"), std=std_o),
    }


def _mask(qpos, kpos, *, causal: bool, window: int | None, chunk: int | None):
    """qpos: (..., S) or (S,); kpos: (T,) — broadcast to (..., S, T)."""
    q = qpos[..., :, None]
    k = kpos[..., None, :]
    m = k >= 0  # ring-buffer slots not yet written carry pos = -1
    if causal:
        m &= k <= q
    if window is not None:
        m &= k > q - window
    if chunk is not None:
        m &= (k // chunk) == (q // chunk)
    return m


def _sdpa(q, k, v, qpos, kpos, *, causal, window, chunk, scale):
    """Direct attention on (B,S,H,D)×(B,T,H,D) with position-based mask."""
    s = jnp.einsum("bshd,bthd->bhst", q, k, preferred_element_type=F32) * scale
    m = _mask(qpos[:, None], kpos, causal=causal, window=window, chunk=chunk)
    s = jnp.where(m[:, :, None] if m.ndim == 3 else m, s, NEG)
    p = jax.nn.softmax(s, axis=-1)
    return jnp.einsum("bhst,bthd->bshd", p.astype(v.dtype), v)


def _blockwise(q, k, v, qpos, kpos, *, causal, window, chunk, scale, block,
               probs_bf16=False):
    """Online-softmax scan over KV blocks. q:(B,S,H,D), k/v:(B,T,H,D)."""
    b, s, h, d = q.shape
    t = k.shape[1]
    if t <= block:
        return _sdpa(q, k, v, qpos, kpos, causal=causal, window=window,
                     chunk=chunk, scale=scale)
    nb = -(-t // block)
    pad = nb * block - t
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        kpos = jnp.pad(kpos, (0, pad), constant_values=-1)
    kb = jnp.moveaxis(k.reshape(b, nb, block, h, d), 1, 0)
    vb = jnp.moveaxis(v.reshape(b, nb, block, h, d), 1, 0)
    pb = kpos.reshape(nb, block)

    def body(carry, blk):
        m_run, l_run, acc = carry
        kblk, vblk, kp = blk
        st = jnp.einsum("bshd,bthd->bhst", q, kblk, preferred_element_type=F32) * scale
        msk = _mask(qpos[:, None], kp, causal=causal, window=window, chunk=chunk)
        st = jnp.where(msk[:, :, None] if msk.ndim == 3 else msk, st, NEG)
        m_new = jnp.maximum(m_run, jnp.max(st, axis=-1))
        p = jnp.exp(st - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        # §Perf lever: bf16 probabilities halve the PV-matmul input traffic
        # and the bwd-saved probability stacks (exactly what a flash kernel
        # keeps in VMEM); accumulation stays f32.
        pv = p.astype(jnp.bfloat16) if probs_bf16 else p
        acc = acc * corr[..., None] + jnp.einsum(
            "bhst,bthd->bhsd", pv, vblk, preferred_element_type=F32
        )
        return (m_new, l_new, acc), None

    m0 = jnp.full((b, h, s), -jnp.inf, F32)
    l0 = jnp.zeros((b, h, s), F32)
    a0 = jnp.zeros((b, h, s, d), F32)
    (m_f, l_f, acc), _ = lax.scan(body, (m0, l0, a0), (kb, vb, pb))
    l_f = jnp.where(l_f == 0.0, 1.0, l_f)
    return jnp.moveaxis(acc / l_f[..., None], 1, 2).astype(q.dtype)


def _local(q, k, v, qpos, *, kind, window, scale):
    """Exact local attention: q-chunk attends [prev ‖ cur] chunk.

    kind = "sliding" (look back `window`, two chunks of size `window`) or
    "chunked" (llama4: attend within the current `window`-sized chunk only).
    """
    b, s, h, d = q.shape
    w = window
    pad = (-s) % w
    if pad:
        q = jnp.pad(q, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        qpos = jnp.pad(qpos, ((0, 0), (0, pad)), constant_values=-(10**9))
    nc = q.shape[1] // w
    qc = q.reshape(b, nc, w, h, d)
    kc = k.reshape(b, nc, w, h, d)
    vc = v.reshape(b, nc, w, h, d)
    pc = qpos.reshape(b, nc, w)
    if kind == "sliding":
        def prev(x):
            return jnp.pad(
                x[:, :-1], ((0, 0), (1, 0)) + ((0, 0),) * (x.ndim - 2),
                constant_values=0,
            )
        kc2 = jnp.concatenate([prev(kc), kc], axis=2)  # (b, nc, 2w, h, d)
        vc2 = jnp.concatenate([prev(vc), vc], axis=2)
        kp2 = jnp.concatenate(
            [jnp.pad(pc[:, :-1], ((0, 0), (1, 0), (0, 0)), constant_values=-1), pc],
            axis=2,
        )
    else:  # chunked: current chunk only
        kc2, vc2, kp2 = kc, vc, pc
    st = jnp.einsum("bcqhd,bckhd->bchqk", qc, kc2, preferred_element_type=F32) * scale
    qp = pc[..., :, None]
    kp = kp2[..., None, :]
    msk = (kp >= 0) & (kp <= qp)
    if kind == "sliding":
        msk &= kp > qp - w
    st = jnp.where(msk[:, :, None], st, NEG)
    p = jax.nn.softmax(st, axis=-1)
    out = jnp.einsum("bchqk,bckhd->bcqhd", p.astype(v.dtype), vc2)
    out = out.reshape(b, nc * w, h, d)
    return out[:, :s] if pad else out


def attention(
    p: dict,
    x: jnp.ndarray,
    cfg,
    positions: jnp.ndarray,
    *,
    kind: str = "full",
    cache: dict | None = None,
    block: int = 1024,
) -> tuple[jnp.ndarray, dict | None]:
    """x: (B, S, d_model). Returns (out, updated_cache).

    Train/prefill: cache is None (prefill cache construction happens in
    serve.steps). Decode: cache holds k/v/pos ring buffers and S == 1.
    """
    b, s, _ = x.shape
    h, hk, dh = cfg.num_heads, cfg.num_kv_heads, cfg.head_dim
    g = h // hk
    scale = dh**-0.5

    q = jnp.einsum("bsd,dhk->bshk", x, p["wq"])
    k = jnp.einsum("bsd,dhk->bshk", x, p["wk"])
    v = jnp.einsum("bsd,dhk->bshk", x, p["wv"])
    q = constrain(q, "batch", "qseq", "heads", None)
    k = constrain(k, "batch", "qseq", "kv_heads", None)
    v = constrain(v, "batch", "qseq", "kv_heads", None)

    rope_pos = positions if positions.ndim > 2 else positions
    if cfg.rope_type == "mrope":
        q = apply_mrope(q, rope_pos, cfg.rope_theta)
        k = apply_mrope(k, rope_pos, cfg.rope_theta)
        pos1d = positions[..., 0]
    elif cfg.rope_type == "rope":
        q = apply_rope(q, rope_pos, cfg.rope_theta)
        k = apply_rope(k, rope_pos, cfg.rope_theta)
        pos1d = positions
    else:
        pos1d = positions if positions.ndim == 2 else positions[..., 0]

    new_cache = None
    if cache is not None:
        # decode: attention runs over the (read-only) cache plus the incoming
        # token as a second softmax block. The cache WRITE is not done here —
        # this layer emits a {k,v,pos} delta and serve.kvcache merges all
        # layers' deltas into the stacked buffers with ONE batched
        # dynamic-update-slice after the period scan. Updating caches inside
        # the scan lets XLA commute score-converts into the update chain and
        # materialize f32 copies of the entire stacked cache (observed:
        # +27 GiB on nemotron decode_32k).
        new_cache = {
            "k_new": k.astype(cache["k"].dtype),
            "v_new": v.astype(cache["v"].dtype),
            "pos_new": pos1d[0, :1].astype(cache["pos"].dtype),
        }
        # SEQ-sharded scores, matching the cache layout (flash-decoding:
        # per-shard partial softmax; re-sharding the cache to heads triggers
        # involuntary full rematerialization)
        kk = constrain(jnp.repeat(cache["k"], g, axis=2),
                       "batch", "model", None, None)
        vv = constrain(jnp.repeat(cache["v"], g, axis=2),
                       "batch", "model", None, None)
        window = cfg.window if kind == "sliding" else None
        chunk = cfg.window if kind == "chunked" else None
        s_old = jnp.einsum("bshd,bthd->bhst", q, kk,
                           preferred_element_type=F32) * scale  # (B,H,1,L)
        msk = _mask(pos1d[:, None], cache["pos"], causal=cfg.causal,
                    window=window, chunk=chunk)
        s_old = jnp.where(msk[:, :, None] if msk.ndim == 3 else msk, s_old, NEG)
        # the slot just overwritten still holds its OLD pos in cache["pos"]:
        # full caches have pos=-1 there (masked); ring caches hold pos-cl,
        # which fails the window/chunk test (masked). The new token is the
        # second block:
        kq = jnp.repeat(k, g, axis=2)
        s_new = jnp.einsum("bshd,bthd->bhst", q, kq,
                           preferred_element_type=F32) * scale  # (B,H,1,1)
        m = jnp.maximum(jnp.max(s_old, axis=-1, keepdims=True), s_new)
        p_old = jnp.exp(s_old - m)
        p_new = jnp.exp(s_new - m)
        denom = jnp.sum(p_old, axis=-1, keepdims=True) + p_new
        out_old = jnp.einsum("bhst,bthd->bshd", p_old.astype(vv.dtype), vv)
        out_new = jnp.einsum(
            "bhst,bthd->bshd", p_new.astype(v.dtype), jnp.repeat(v, g, axis=2)
        )
        out = (out_old + out_new) / jnp.moveaxis(denom, 1, 2).astype(out_old.dtype)
    else:
        kk = jnp.repeat(k, g, axis=2)
        vv = jnp.repeat(v, g, axis=2)
        kk = constrain(kk, "batch", "qseq", "heads", None)
        vv = constrain(vv, "batch", "qseq", "heads", None)
        if kind in ("sliding", "chunked") and cfg.window and 1 < cfg.window < s:
            out = _local(q, kk, vv, pos1d, kind=kind, window=cfg.window,
                         scale=scale)
        else:
            kpos = pos1d[0]  # assumes aligned positions across batch
            out = _blockwise(q, kk, vv, pos1d, kpos, causal=cfg.causal,
                             window=None, chunk=None, scale=scale,
                             block=cfg.attn_block,
                             probs_bf16=cfg.attn_probs_bf16)
    out = constrain(out, "batch", "qseq", "heads", None)
    y = jnp.einsum("bshk,hkd->bsd", out, p["wo"])
    y = constrain(y, "batch", "seq", None)
    return y, new_cache
