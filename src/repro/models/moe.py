"""Mixture-of-Experts FFN with top-k routing.

Two interchangeable implementations (cfg.moe_impl):

  * "dense"    — every expert computes every token; router weights zero out
                 the unused ones at combine. Simple, collective-free,
                 O(E/k)× wasted FLOPs. The §Perf baseline.
  * "dispatch" — capacity-bounded one-hot dispatch (MaxText-style expert
                 parallelism): tokens are gathered into per-expert buffers
                 via a dispatch einsum, experts are sharded over the model
                 axis, outputs combined with routing weights. Compute is
                 O(k·capacity_factor / E) of dense — the §Perf optimized
                 path for the MoE archs.

Router: softmax over expert logits, top-k, weights renormalized over the
selected experts (Mixtral/Llama4 convention).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distrib.sharding import constrain
from .common import Initializer

ACT = {
    "swiglu": jax.nn.silu,
    "geglu": jax.nn.gelu,
    "relu2": lambda x: jnp.square(jax.nn.relu(x)),
    "gelu": jax.nn.gelu,
}


def init_moe(ini: Initializer, cfg) -> dict:
    d, f, e = cfg.d_model, cfg.d_ff, cfg.num_experts
    std_o = 0.02 / (2 * cfg.num_layers) ** 0.5
    return {
        "router": ini.normal((d, e), ("fsdp", None), dtype=jnp.float32),
        "w_gate": ini.normal((e, d, f), ("model", "fsdp", None)),
        "w_up": ini.normal((e, d, f), ("model", "fsdp", None)),
        "w_down": ini.normal((e, f, d), ("model", "fsdp", None), std=std_o),
    }


def _routing(p, x, cfg):
    """x: (T, d) flat tokens → (weights (T,k) f32, idx (T,k) int)."""
    logits = x.astype(jnp.float32) @ p["router"]
    k = cfg.experts_per_token
    w, idx = jax.lax.top_k(jax.nn.softmax(logits, axis=-1), k)
    w = w / jnp.sum(w, axis=-1, keepdims=True)
    return w, idx


def _expert_ffn(p, h, cfg):
    """h: (E, C, d) per-expert token buffers → (E, C, d)."""
    act = ACT[cfg.mlp_type]
    gate = jnp.einsum("ecd,edf->ecf", h, p["w_gate"])
    up = jnp.einsum("ecd,edf->ecf", h, p["w_up"])
    out = act(gate) * up
    return jnp.einsum("ecf,efd->ecd", out, p["w_down"])


def apply_moe(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    b, s, d = x.shape
    xt = x.reshape(b * s, d)
    w, idx = _routing(p, xt, cfg)
    e = cfg.num_experts

    if cfg.moe_impl == "dense":
        # all experts on all tokens; combine = Σ_k w_k · out[idx_k]
        act = ACT[cfg.mlp_type]
        gate = jnp.einsum("td,edf->etf", xt, p["w_gate"])
        up = jnp.einsum("td,edf->etf", xt, p["w_up"])
        out = jnp.einsum("etf,efd->etd", act(gate) * up, p["w_down"])  # (E,T,d)
        onehot = jax.nn.one_hot(idx, e, dtype=w.dtype) * w[..., None]  # (T,k,E)
        comb = jnp.einsum("tke,etd->td", onehot, out.astype(w.dtype))
        y = comb.astype(x.dtype)
    else:  # dispatch — token-grouped (see module docstring)
        t = b * s
        tg = min(cfg.moe_group, t)
        while t % tg != 0:
            tg //= 2
        g = t // tg
        cap = max(int(cfg.moe_capacity_factor * tg * cfg.experts_per_token / e), 1)
        # per-group slot assignment: position of each (token, choice) within
        # its expert's buffer, computed independently per group so the
        # dispatch tensor is O(T·tg·k), not O(T²·k/E)
        onehot = jax.nn.one_hot(idx, e, dtype=jnp.int32).reshape(g, tg, -1, e)
        pos_in_e = jnp.cumsum(onehot.reshape(g, -1, e), axis=1).reshape(
            g, tg, -1, e
        ) - 1
        keep = (pos_in_e < cap) & (onehot > 0)
        slot = jnp.where(keep, pos_in_e, cap)  # cap == overflow bucket
        disp = jax.nn.one_hot(slot, cap + 1, dtype=x.dtype)[..., :cap]
        disp = jnp.sum(disp * keep[..., None].astype(x.dtype), axis=2)  # (G,tg,E,cap)
        gspec = "batch" if s > 1 else None  # groups follow batch sharding
        disp = constrain(disp, gspec, None, "model", None)
        xg = constrain(xt.reshape(g, tg, d), gspec, None, None)
        h = jnp.einsum("gtec,gtd->gecd", disp, xg)  # (G, E, cap, d)
        h = constrain(h, gspec, "model", None, None)
        out = jnp.einsum(
            "gecf,efd->gecd",
            ACT[cfg.mlp_type](jnp.einsum("gecd,edf->gecf", h, p["w_gate"]))
            * jnp.einsum("gecd,edf->gecf", h, p["w_up"]),
            p["w_down"],
        )
        out = constrain(out, gspec, "model", None, None)
        wk = jnp.einsum(
            "gtke,gtk->gte",
            jnp.asarray(onehot, w.dtype) * keep.astype(w.dtype),
            w.reshape(g, tg, -1),
        ).astype(x.dtype)
        combine = constrain(disp * wk[..., None], gspec, None, "model", None)
        y = jnp.einsum("gtec,gecd->gtd", combine, out)
        y = constrain(y, gspec, None, None).reshape(t, d)
    return constrain(y.reshape(b, s, d), "batch", "seq", None)


def moe_active_params(cfg) -> int:
    """Per-token active expert params (for MODEL_FLOPS accounting)."""
    per_expert = 3 * cfg.d_model * cfg.d_ff
    return cfg.experts_per_token * per_expert + cfg.d_model * cfg.num_experts
