"""Full model assembly: embeddings / stub frontends → period stack → norm →
(chunked) logits + loss. Decoder-only LMs and the encoder-only audio arch
share this file (cfg.causal distinguishes them).

Vocab handling: the table is padded to a multiple of 128·model_size so the
vocab axis always shards evenly; padded logit slots are masked to -inf
before any softmax/CE so numerics are exact w.r.t. the true vocab.

Cross-entropy is computed in seq-chunks (lax.scan) so the (B, S, V) logits
tensor never materializes — at gemma3's 262k vocab that is the difference
between a 2 GiB and a 130 MiB per-device transient (DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distrib.sharding import constrain
from .blocks import apply_stack, init_stack
from .common import Initializer, apply_norm, init_norm, positions_for

F32 = jnp.float32


def padded_vocab(cfg, multiple: int = 2048) -> int:
    v = cfg.vocab_size
    return -(-v // multiple) * multiple


def init_lm(cfg, key: jax.Array) -> dict:
    """Px-tree of all model params (run under eval_shape for dry-runs)."""
    ini = Initializer(key, dtype=cfg.param_dtype)
    vp = padded_vocab(cfg)
    params: dict = {}
    if cfg.frontend is None:
        params["embed"] = ini.normal((vp, cfg.d_model), ("model", "fsdp"))
    else:
        # stub frontend: inputs arrive as precomputed embeddings; a single
        # linear adapter stands in for the patch/frame projection
        params["frontend"] = {
            "adapter": ini.normal((cfg.d_model, cfg.d_model), ("fsdp", None))
        }
    params["stack"] = init_stack(ini, cfg)
    params["final_norm"] = init_norm(ini, cfg.d_model, cfg.norm_type)
    params["lm_head"] = ini.normal((cfg.d_model, vp), ("fsdp", "model"))
    return params


def embed_inputs(params: dict, batch: dict, cfg) -> jnp.ndarray:
    if cfg.frontend is None:
        x = jnp.take(params["embed"], batch["tokens"], axis=0)
    else:
        x = batch["embeds"].astype(cfg.dtype) @ params["frontend"]["adapter"]
    return constrain(x.astype(cfg.dtype), "batch", "seq", None)


def forward_hidden(
    params: dict,
    batch: dict,
    cfg,
    positions: jnp.ndarray | None = None,
    caches: dict | None = None,
    *,
    remat_policy: str = "nothing",
) -> tuple[jnp.ndarray, dict | None]:
    x = embed_inputs(params, batch, cfg)
    b, s = x.shape[:2]
    if positions is None:
        positions = positions_for(cfg, b, s)
    x, cache_updates = apply_stack(
        params["stack"], x, cfg, positions, caches, remat_policy=remat_policy
    )
    new_caches = None
    if caches is not None:
        # fold every layer's decode delta into the stacked cache buffers in
        # one batched update (outside the scan — see serve/kvcache.py)
        from repro.serve.kvcache import merge_cache_updates

        new_caches = merge_cache_updates(caches, cache_updates)
    x = apply_norm(params["final_norm"], x, cfg.norm_type)
    return x, new_caches


def _chunk_ce(hidden, labels, head, cfg, chunk: int):
    """Chunked cross-entropy over seq. hidden: (B,S,D), labels: (B,S)."""
    b, s, d = hidden.shape
    vp = head.shape[1]
    chunk = min(chunk, s)
    while s % chunk != 0:
        chunk //= 2
    nc = s // chunk
    hc = jnp.moveaxis(hidden.reshape(b, nc, chunk, d), 1, 0)
    lc = jnp.moveaxis(labels.reshape(b, nc, chunk), 1, 0)
    vocab_ok = jnp.arange(vp) < cfg.vocab_size

    def body(acc, inp):
        h, lab = inp
        logits = (h @ head).astype(F32)
        logits = jnp.where(vocab_ok, logits, -1e30)
        logits = constrain(logits, "batch", None, "model")
        lse = jax.nn.logsumexp(logits, axis=-1)
        gold = jnp.take_along_axis(logits, lab[..., None], axis=-1)[..., 0]
        mask = (lab >= 0).astype(F32)
        loss = jnp.sum((lse - gold) * mask)
        return (acc[0] + loss, acc[1] + jnp.sum(mask)), None

    (tot, cnt), _ = lax.scan(body, (jnp.zeros((), F32), jnp.zeros((), F32)),
                             (hc, lc))
    return tot / jnp.maximum(cnt, 1.0)


def lm_loss(
    params: dict, batch: dict, cfg, *, remat_policy: str = "nothing",
    ce_chunk: int = 512,
) -> jnp.ndarray:
    """Mean next-token (decoder) or per-position (encoder) CE loss."""
    hidden, _ = forward_hidden(params, batch, cfg, remat_policy=remat_policy)
    labels = batch["labels"]
    if cfg.causal:
        # shift labels left, mask the last position (-1) — keeps S intact so
        # the CE chunking divides evenly (4096, not 4095)
        labels = jnp.concatenate(
            [labels[:, 1:], jnp.full_like(labels[:, :1], -1)], axis=1
        )
    return _chunk_ce(hidden, labels, params["lm_head"], cfg, ce_chunk)


def lm_logits_last(params: dict, hidden: jnp.ndarray, cfg) -> jnp.ndarray:
    """Logits for the last position only (decode path)."""
    logits = (hidden[:, -1] @ params["lm_head"]).astype(F32)
    vp = params["lm_head"].shape[1]
    logits = jnp.where(jnp.arange(vp) < cfg.vocab_size, logits, -1e30)
    return constrain(logits, "batch", "model")
