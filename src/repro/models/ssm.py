"""Mamba2 / SSD (state-space duality) mixer — arXiv:2405.21060, adapted to
TPU-idiomatic JAX: the chunked SSD algorithm is three einsum families
(intra-chunk quadratic, chunk-state build, inter-chunk recurrence), all
MXU-shaped, with a lax.scan only over the O(S/Q) chunk recurrence.

Discretization: h_t = exp(dt_t·A) h_{t-1} + dt_t B_t x_t;  y_t = C_t h_t + D x_t.
Heads are sharded over the model axis (H % model_size == 0 for both SSM
archs); B/C are single-group (G=1) and replicated — they are O(N) per token.

Decode is the O(1) recurrence — this is why mamba2/jamba run the 500k
decode shape at constant cost per token (DESIGN.md §4).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
from jax import lax

from repro.distrib.sharding import constrain
from .common import Initializer, rms_norm

F32 = jnp.float32


def init_ssm(ini: Initializer, cfg) -> dict:
    d = cfg.d_model
    di = cfg.ssm_expand * d
    h, p, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    assert h * p == di, (h, p, di)
    std_o = 0.02 / (2 * cfg.num_layers) ** 0.5
    return {
        "w_z": ini.normal((d, di), ("fsdp", "model")),
        "w_x": ini.normal((d, di), ("fsdp", "model")),
        "w_B": ini.normal((d, n), ("fsdp", None)),
        "w_C": ini.normal((d, n), ("fsdp", None)),
        "w_dt": ini.normal((d, h), ("fsdp", "model")),
        "conv_x": ini.normal((4, di), (None, "model"), std=0.2),
        "conv_B": ini.normal((4, n), (None, None), std=0.2),
        "conv_C": ini.normal((4, n), (None, None), std=0.2),
        "A_log": ini.zeros((h,), ("model",), dtype=F32),
        "D": ini.ones((h,), ("model",), dtype=F32),
        "dt_bias": ini.zeros((h,), ("model",), dtype=F32),
        "norm_gamma": ini.zeros((di,), ("model",)),
        "w_out": ini.normal((di, d), ("model", "fsdp"), std=std_o),
    }


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray) -> jnp.ndarray:
    """Depthwise causal conv, width K, as K shifted adds. x: (B,S,C), w: (K,C)."""
    k = w.shape[0]
    xp = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    s = x.shape[1]
    out = jnp.zeros_like(x, dtype=F32)
    for i in range(k):
        out = out + w[i].astype(F32) * xp[:, i : i + s].astype(F32)
    return out.astype(x.dtype)


def _ssd_chunked(xd, la, Bc, Cc, chunk: int):
    """Chunked SSD. xd: (B,S,H,P) dt-scaled inputs; la: (B,S,H) log-decay;
    Bc/Cc: (B,S,N). Returns y: (B,S,H,P) and final state (B,H,N,P)."""
    b, s, h, p = xd.shape
    n = Bc.shape[-1]
    q = min(chunk, s)
    pad = (-s) % q
    if pad:
        xd = jnp.pad(xd, ((0, 0), (0, pad), (0, 0), (0, 0)))
        la = jnp.pad(la, ((0, 0), (0, pad), (0, 0)))
        Bc = jnp.pad(Bc, ((0, 0), (0, pad), (0, 0)))
        Cc = jnp.pad(Cc, ((0, 0), (0, pad), (0, 0)))
    nc = xd.shape[1] // q
    xd = xd.reshape(b, nc, q, h, p)
    la = la.reshape(b, nc, q, h).astype(F32)
    Bc = Bc.reshape(b, nc, q, n)
    Cc = Cc.reshape(b, nc, q, n)

    cum = jnp.cumsum(la, axis=2)  # (b,nc,q,h)
    # --- intra-chunk (quadratic within q) ---
    scores = jnp.einsum("bcin,bcjn->bcij", Cc, Bc, preferred_element_type=F32)
    ii = jnp.arange(q)[:, None]
    jj = jnp.arange(q)[None, :]
    ldecay = cum[:, :, :, None, :] - cum[:, :, None, :, :]  # (b,nc,i,j,h)
    w_ij = jnp.where(
        (ii >= jj)[None, None, :, :, None],
        jnp.exp(ldecay) * scores[..., None],
        0.0,
    )
    y_intra = jnp.einsum("bcijh,bcjhp->bcihp", w_ij, xd.astype(F32))

    # --- chunk states ---
    decay_end = jnp.exp(cum[:, :, -1:, :] - cum)  # (b,nc,q,h)
    st = jnp.einsum(
        "bcjn,bcjh,bcjhp->bchnp", Bc.astype(F32), decay_end, xd.astype(F32)
    )

    # --- inter-chunk recurrence (scan over chunks) ---
    chunk_decay = jnp.exp(cum[:, :, -1, :])  # (b,nc,h)
    Cs = jnp.moveaxis(Cc, 1, 0)
    cums = jnp.moveaxis(cum, 1, 0)
    sts = jnp.moveaxis(st, 1, 0)
    cds = jnp.moveaxis(chunk_decay, 1, 0)

    def body(hstate, inp):
        c_c, cum_c, st_c, cd_c = inp
        y = jnp.einsum(
            "bin,bhnp,bih->bihp", c_c.astype(F32), hstate, jnp.exp(cum_c)
        )
        hstate = hstate * cd_c[:, :, None, None] + st_c
        return hstate, y

    h0 = jnp.zeros((b, h, n, p), F32)
    hfin, y_inter = lax.scan(body, h0, (Cs, cums, sts, cds))
    y_inter = jnp.moveaxis(y_inter, 0, 1)  # (b,nc,q,h,p)

    y = (y_intra + y_inter).reshape(b, nc * q, h, p)
    if pad:
        y = y[:, :s]
    return y.astype(xd.dtype), hfin


def apply_ssm(
    p: dict, x: jnp.ndarray, cfg, *, cache: dict | None = None
) -> tuple[jnp.ndarray, dict | None]:
    """x: (B, S, d_model). cache (decode): {"state": (B,H,N,P),
    "conv": (B, 3, C_conv)} with C_conv = d_inner + 2N."""
    b, s, d = x.shape
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di = h * pd

    z = x @ p["w_z"]
    xs = x @ p["w_x"]
    Bc = x @ p["w_B"]
    Cc = x @ p["w_C"]
    dt = (x @ p["w_dt"]).astype(F32)
    xs = constrain(xs, "batch", None, "model")
    z = constrain(z, "batch", None, "model")

    conv_in = jnp.concatenate([xs, Bc, Cc], axis=-1)
    conv_w = jnp.concatenate([p["conv_x"], p["conv_B"], p["conv_C"]], axis=-1)
    new_cache = None
    if cache is None:
        conv_out = jax.nn.silu(_causal_conv(conv_in, conv_w).astype(F32))
    else:
        hist = jnp.concatenate([cache["conv"], conv_in], axis=1)  # (B,4,C)
        conv_out = jax.nn.silu(
            jnp.sum(conv_w.astype(F32) * hist.astype(F32), axis=1, keepdims=True)
        )
        new_conv = hist[:, 1:]
    xs = conv_out[..., :di].astype(x.dtype)
    Bc = conv_out[..., di : di + n].astype(x.dtype)
    Cc = conv_out[..., di + n :].astype(x.dtype)

    a = -jnp.exp(p["A_log"].astype(F32))  # (H,)
    dt = jax.nn.softplus(dt + p["dt_bias"].astype(F32))  # (B,S,H)
    xh = xs.reshape(b, s, h, pd)
    xd = xh * dt[..., None].astype(x.dtype)
    la = dt * a  # log decay

    if cache is None:
        y, _ = _ssd_chunked(xd, la, Bc, Cc, cfg.ssm_chunk)
    else:
        state = cache["state"]  # (B,H,N,P)
        alpha = jnp.exp(la[:, 0])  # (B,H)
        state = state * alpha[:, :, None, None] + jnp.einsum(
            "bn,bhp->bhnp", Bc[:, 0].astype(F32), xd[:, 0].astype(F32)
        )
        y = jnp.einsum("bn,bhnp->bhp", Cc[:, 0].astype(F32), state)[:, None]
        new_cache = {"state": state, "conv": new_conv}

    y = y + p["D"].astype(F32)[None, None, :, None] * xh.astype(F32)
    y = y.reshape(b, s, di).astype(x.dtype)
    y = rms_norm(y * jax.nn.silu(z.astype(F32)).astype(x.dtype), p["norm_gamma"])
    y = constrain(y, "batch", None, "model")
    out = y @ p["w_out"]
    return constrain(out, "batch", "seq", None), new_cache


def init_ssm_cache(cfg, batch: int, dtype=jnp.float32) -> dict:
    h, pd, n = cfg.ssm_heads, cfg.ssm_head_dim, cfg.ssm_state
    di = h * pd
    return {
        "state": jnp.zeros((batch, h, n, pd), F32),
        "conv": jnp.zeros((batch, 3, di + 2 * n), dtype),
    }
