"""Shared model machinery: spec-carrying params, norms, RoPE variants.

Params are built as trees of `Px(value, spec)` — every leaf carries its
logical PartitionSpec from birth, so the init function *is* the sharding
map (no drift between a params tree and a separate spec tree).
`split_tree` peels them apart for jit in_shardings / checkpointing.
Init can run under jax.eval_shape for allocation-free dry-runs.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp

from repro.distrib.sharding import constrain


@jax.tree_util.register_pytree_node_class
class Px:
    """A param leaf carrying its logical PartitionSpec as pytree aux data —
    transparent to tracing (eval_shape of a 340B init never sees the spec
    strings), opaque to split_tree (is_leaf=is_px)."""

    __slots__ = ("value", "spec")

    def __init__(self, value: Any, spec: tuple):
        self.value = value
        self.spec = tuple(spec)

    def tree_flatten(self):
        return (self.value,), self.spec

    @classmethod
    def tree_unflatten(cls, aux, children):
        return cls(children[0], aux)

    def __repr__(self):
        shape = getattr(self.value, "shape", None)
        return f"Px(shape={shape}, spec={self.spec})"


def is_px(x) -> bool:
    return isinstance(x, Px)


def split_tree(tree):
    """(params, logical_specs) from a Px tree."""
    params = jax.tree.map(lambda p: p.value, tree, is_leaf=is_px)
    specs = jax.tree.map(lambda p: p.spec, tree, is_leaf=is_px)
    return params, specs


class Initializer:
    """Deterministic per-path param factory (splittable like a PRNG key)."""

    def __init__(self, key: jax.Array, dtype=jnp.bfloat16):
        self.key = key
        self.dtype = dtype
        self._n = 0

    def _next(self):
        self._n += 1
        return jax.random.fold_in(self.key, self._n)

    def normal(self, shape, spec, *, std=0.02, dtype=None) -> Px:
        d = dtype or self.dtype
        v = (jax.random.normal(self._next(), shape, jnp.float32) * std).astype(d)
        return Px(v, spec)

    def zeros(self, shape, spec, *, dtype=None) -> Px:
        return Px(jnp.zeros(shape, dtype or self.dtype), spec)

    def ones(self, shape, spec, *, dtype=None) -> Px:
        return Px(jnp.ones(shape, dtype or self.dtype), spec)

    def value(self, v, spec) -> Px:
        return Px(v, spec)


# ---------------------------------------------------------------------------
# norms (computed in f32, cast back)
# ---------------------------------------------------------------------------
def rms_norm(x: jnp.ndarray, gamma: jnp.ndarray, eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    var = jnp.mean(xf * xf, axis=-1, keepdims=True)
    out = xf * jax.lax.rsqrt(var + eps) * (1.0 + gamma.astype(jnp.float32))
    return out.astype(x.dtype)


def layer_norm(
    x: jnp.ndarray, gamma: jnp.ndarray, beta: jnp.ndarray, eps: float = 1e-6
) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    mu = jnp.mean(xf, axis=-1, keepdims=True)
    var = jnp.var(xf, axis=-1, keepdims=True)
    out = (xf - mu) * jax.lax.rsqrt(var + eps)
    out = out * gamma.astype(jnp.float32) + beta.astype(jnp.float32)
    return out.astype(x.dtype)


def init_norm(ini: Initializer, d: int, kind: str = "rmsnorm"):
    if kind == "rmsnorm":
        return {"gamma": ini.zeros((d,), (None,))}
    return {"gamma": ini.ones((d,), (None,)), "beta": ini.zeros((d,), (None,))}


def apply_norm(p, x, kind: str = "rmsnorm"):
    if kind == "rmsnorm":
        return rms_norm(x, p["gamma"])
    return layer_norm(x, p["gamma"], p["beta"])


# ---------------------------------------------------------------------------
# RoPE (half-split convention) + M-RoPE (Qwen2-VL §3.1)
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float) -> jnp.ndarray:
    return 1.0 / (theta ** (jnp.arange(0, head_dim, 2, dtype=jnp.float32) / head_dim))


def apply_rope(
    x: jnp.ndarray, positions: jnp.ndarray, theta: float = 1e4
) -> jnp.ndarray:
    """x: (B, S, H, D); positions: (B, S) int."""
    d = x.shape[-1]
    freqs = rope_freqs(d, theta)  # (d/2,)
    ang = positions[..., None].astype(jnp.float32) * freqs  # (B, S, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def mrope_sections(head_dim: int) -> tuple[int, int, int]:
    """Qwen2-VL's (temporal, h, w) = (16, 24, 24) of the 64 freq slots at
    head_dim 128, generalized proportionally (1/4, 3/8, 3/8)."""
    half = head_dim // 2
    t = half // 4
    h = (half - t) // 2
    return (t, h, half - t - h)


def apply_mrope(
    x: jnp.ndarray,
    positions: jnp.ndarray,
    theta: float = 1e4,
    sections: tuple[int, int, int] | None = None,
) -> jnp.ndarray:
    """M-RoPE: head_dim/2 freq slots split into (temporal, h, w) sections,
    each rotated by its own position stream. positions: (B, S, 3) — for the
    text-only backbone all three streams equal the text position (exactly
    Qwen2-VL's behavior on text tokens).
    """
    d = x.shape[-1]
    if sections is None:
        sections = mrope_sections(d)
    assert sum(sections) == d // 2, (sections, d)
    freqs = rope_freqs(d, theta)  # (d/2,)
    sec_id = jnp.repeat(
        jnp.arange(3), jnp.asarray(sections), total_repeat_length=d // 2
    )  # (d/2,) which position stream each freq slot uses
    pos = positions.astype(jnp.float32)  # (B, S, 3)
    pos_per_slot = jnp.take(pos, sec_id, axis=-1)  # (B, S, d/2)
    ang = pos_per_slot * freqs  # (B, S, d/2)
    cos = jnp.cos(ang)[:, :, None, :]
    sin = jnp.sin(ang)[:, :, None, :]
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def positions_for(cfg, batch: int, seq: int, offset: int | jnp.ndarray = 0):
    """Position stream(s) for a text segment starting at `offset`."""
    pos = jnp.arange(seq)[None, :] + jnp.asarray(offset).reshape(-1, 1)
    pos = jnp.broadcast_to(pos, (batch, seq))
    if cfg.rope_type == "mrope":
        return jnp.repeat(pos[..., None], 3, axis=-1)
    return pos


__all__ = [
    "Px", "is_px", "split_tree", "Initializer",
    "rms_norm", "layer_norm", "init_norm", "apply_norm",
    "apply_rope", "apply_mrope", "positions_for", "constrain",
]
