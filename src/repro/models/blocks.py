"""Layer composition: pre-norm residual blocks and the period structure.

Heterogeneous stacks (jamba's 1-attn:7-mamba, gemma3's 5-local:1-global,
llama4's 3-chunked:1-global) are expressed as a *pattern* — a tuple of
(mixer, ffn) kinds forming one period. The model scans over periods
(params stacked on a leading period axis) so HLO size is O(pattern), not
O(num_layers); layers beyond the last full period ("remainder") are
applied unrolled. This keeps 96-layer × 512-device compiles tractable and
matches how these models are actually built (repeating superblocks).

mixer ∈ {"attn_full", "attn_sliding", "attn_chunked", "ssm"}
ffn   ∈ {"mlp", "moe", "none"}
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from .attention import attention, init_attention
from .common import Initializer, apply_norm, init_norm
from .mlp import apply_mlp, init_mlp
from .moe import apply_moe, init_moe
from .ssm import apply_ssm, init_ssm


def init_layer(ini: Initializer, cfg, mixer: str, ffn: str) -> dict:
    p = {"mixer_norm": init_norm(ini, cfg.d_model, cfg.norm_type)}
    if mixer == "ssm":
        p["mixer"] = init_ssm(ini, cfg)
    else:
        p["mixer"] = init_attention(ini, cfg)
    if ffn != "none":
        p["ffn_norm"] = init_norm(ini, cfg.d_model, cfg.norm_type)
        p["ffn"] = init_moe(ini, cfg) if ffn == "moe" else init_mlp(ini, cfg)
    return p


def apply_layer(
    p: dict,
    x: jnp.ndarray,
    cfg,
    mixer: str,
    ffn: str,
    positions: jnp.ndarray,
    cache: dict | None = None,
) -> tuple[jnp.ndarray, dict | None]:
    h = apply_norm(p["mixer_norm"], x, cfg.norm_type)
    if mixer == "ssm":
        mx, new_cache = apply_ssm(p["mixer"], h, cfg, cache=cache)
    else:
        kind = {"attn_full": "full", "attn_sliding": "sliding",
                "attn_chunked": "chunked"}[mixer]
        mx, new_cache = attention(p["mixer"], h, cfg, positions, kind=kind,
                                  cache=cache)
    x = x + mx
    if ffn != "none":
        h = apply_norm(p["ffn_norm"], x, cfg.norm_type)
        f = apply_moe(p["ffn"], h, cfg) if ffn == "moe" else apply_mlp(p["ffn"], h, cfg)
        x = x + f
    return x, new_cache


def split_layers(cfg) -> tuple[int, int]:
    """(num_full_periods, num_remainder_layers)."""
    plen = len(cfg.pattern)
    return cfg.num_layers // plen, cfg.num_layers % plen


def init_stack(ini: Initializer, cfg) -> dict:
    """Stacked per-period params + unrolled remainder params."""
    from .common import Px, is_px

    n_periods, rem = split_layers(cfg)

    def one_period():
        return {
            f"l{i}": init_layer(ini, cfg, mixer, ffn)
            for i, (mixer, ffn) in enumerate(cfg.pattern)
        }

    periods = [one_period() for _ in range(n_periods)]
    stacked = jax.tree.map(
        lambda *ps: Px(jnp.stack([p.value for p in ps]), (None,) + ps[0].spec),
        *periods,
        is_leaf=is_px,
    )
    out = {"periods": stacked}
    if rem:
        out["remainder"] = {
            f"l{i}": init_layer(ini, cfg, *cfg.pattern[i]) for i in range(rem)
        }
    return out


def apply_stack(
    params: dict,
    x: jnp.ndarray,
    cfg,
    positions: jnp.ndarray,
    caches: dict | None = None,
    *,
    remat_policy: str = "nothing",
) -> tuple[jnp.ndarray, dict | None]:
    """Scan over periods (+ unrolled remainder). caches mirror the params
    structure ({"periods": stacked-per-period, "remainder": {...}})."""
    n_periods, rem = split_layers(cfg)
    decode = caches is not None

    def period_body(x, inputs):
        pp, pc = inputs
        new_pc = {}
        for i, (mixer, ffn) in enumerate(cfg.pattern):
            x, nc = apply_layer(
                pp[f"l{i}"], x, cfg, mixer, ffn, positions,
                cache=None if pc is None else pc[f"l{i}"],
            )
            if nc is not None:
                new_pc[f"l{i}"] = nc
        return x, (new_pc if decode else None)

    body = period_body
    if not decode and remat_policy != "none":
        policy = (
            jax.checkpoint_policies.dots_with_no_batch_dims_saveable
            if remat_policy == "dots"
            else jax.checkpoint_policies.nothing_saveable
        )
        body = jax.checkpoint(period_body, policy=policy)

    if n_periods > 0:
        pc = caches["periods"] if decode else None
        xs = (params["periods"], pc) if decode else (params["periods"], None)
        if decode:
            x, new_caches = jax.lax.scan(body, x, xs)
        else:
            x, _ = jax.lax.scan(lambda c, pp: body(c, (pp, None)), x,
                                params["periods"])
            new_caches = None
    else:
        new_caches = None

    new_rem = {}
    if rem:
        for i in range(rem):
            mixer, ffn = cfg.pattern[i]
            x, nc = apply_layer(
                params["remainder"][f"l{i}"], x, cfg, mixer, ffn, positions,
                cache=None if not decode else caches["remainder"][f"l{i}"],
            )
            if nc is not None:
                new_rem[f"l{i}"] = nc

    if decode:
        out_caches = {"periods": new_caches}
        if rem:
            out_caches["remainder"] = new_rem
        return x, out_caches
    return x, None
