"""Feed-forward variants: SwiGLU / GeGLU (gated), squared-ReLU / GELU
(non-gated). Column-parallel in → row-parallel out: w_in sharded on d_ff,
w_out sharded on its d_ff input dim, so each block costs exactly one psum
(inserted by the partitioner at the w_out contraction).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.distrib.sharding import constrain
from .common import Initializer

GATED = {"swiglu": jax.nn.silu, "geglu": jax.nn.gelu}
PLAIN = {"relu2": lambda x: jnp.square(jax.nn.relu(x)), "gelu": jax.nn.gelu}


def init_mlp(ini: Initializer, cfg, d_ff: int | None = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    std_o = 0.02 / (2 * cfg.num_layers) ** 0.5
    if cfg.mlp_type in GATED:
        return {
            "w_gate": ini.normal((d, f), ("fsdp", "model")),
            "w_up": ini.normal((d, f), ("fsdp", "model")),
            "w_down": ini.normal((f, d), ("model", "fsdp"), std=std_o),
        }
    return {
        "w_up": ini.normal((d, f), ("fsdp", "model")),
        "w_down": ini.normal((f, d), ("model", "fsdp"), std=std_o),
    }


def apply_mlp(p: dict, x: jnp.ndarray, cfg) -> jnp.ndarray:
    if cfg.mlp_type in GATED:
        act = GATED[cfg.mlp_type]
        h = act(x @ p["w_gate"]) * (x @ p["w_up"])
    else:
        act = PLAIN[cfg.mlp_type]
        h = act(x @ p["w_up"])
    h = constrain(h, "batch", None, "model")
    y = h @ p["w_down"]
    return constrain(y, "batch", "seq", None)
