"""Config system: ModelConfig (architecture), ShapeConfig (workload), and
the applicability rules deciding which (arch × shape) cells run
(DESIGN.md §4).
"""
from __future__ import annotations

from dataclasses import dataclass, field

import jax.numpy as jnp

_DTYPES = {"bfloat16": jnp.bfloat16, "float32": jnp.float32,
           "float16": jnp.float16}


@dataclass(frozen=True)
class ModelConfig:
    name: str
    family: str  # dense | moe | ssm | hybrid | vlm | audio
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    head_dim: int
    d_ff: int
    vocab_size: int
    # one period of the layer pattern: ((mixer, ffn), ...)
    pattern: tuple = ((("attn_full", "mlp")),)
    mlp_type: str = "swiglu"
    norm_type: str = "rmsnorm"
    rope_theta: float = 1e4
    rope_type: str = "rope"  # rope | mrope | none
    causal: bool = True
    window: int | None = None  # sliding window / chunk size for local layers
    # moe
    num_experts: int = 0
    experts_per_token: int = 0
    moe_impl: str = "dispatch"  # dense | dispatch
    moe_capacity_factor: float = 1.25
    moe_group: int = 1024  # tokens per dispatch group (bounds the one-hot)
    # ssm
    ssm_state: int = 0
    ssm_heads: int = 0
    ssm_head_dim: int = 64
    ssm_expand: int = 2
    ssm_chunk: int = 256
    # stubs / misc
    frontend: str | None = None  # vision | audio
    long_ok: bool = False  # sub-quadratic attention => long_500k runs
    # perf knobs (§Perf hillclimb levers; defaults = paper-faithful baseline)
    attn_block: int = 1024  # KV block for blockwise attention
    attn_probs_bf16: bool = False  # cast softmax probs to bf16 before PV
    use_fsdp: bool = True  # shard params over the data axes (ZeRO-3)
    dp_over_model: bool = False  # small-model strategy: batch over BOTH mesh
    # axes (no TP; params FSDP-sharded over all 256/512 chips)
    # numerics & memory policy
    activation_dtype: str = "bfloat16"
    params_dtype: str = "bfloat16"
    optimizer_dtype: str = "float32"
    grad_accum: int = 1  # microbatch steps per train step
    remat: str = "nothing"  # nothing | dots | none
    notes: str = ""

    @property
    def dtype(self):
        return _DTYPES[self.activation_dtype]

    @property
    def param_dtype(self):
        return _DTYPES[self.params_dtype]

    @property
    def opt_dtype(self):
        return _DTYPES[self.optimizer_dtype]

    def param_count(self) -> int:
        """Total parameters (analytic, excludes vocab padding)."""
        d, f, v = self.d_model, self.d_ff, self.vocab_size
        total = 2 * v * d  # embed + head (untied)
        n_attn = sum(1 for m, _ in self.layer_list() if m != "ssm")
        n_ssm = sum(1 for m, _ in self.layer_list() if m == "ssm")
        n_mlp = sum(1 for _, fk in self.layer_list() if fk == "mlp")
        n_moe = sum(1 for _, fk in self.layer_list() if fk == "moe")
        attn = (self.num_heads + 2 * self.num_kv_heads) * self.head_dim * d \
            + self.num_heads * self.head_dim * d
        di = self.ssm_expand * d
        ssm = 2 * d * di + 2 * d * self.ssm_state + d * self.ssm_heads \
            + 4 * (di + 2 * self.ssm_state) + 3 * self.ssm_heads + di + di * d
        gated = self.mlp_type in ("swiglu", "geglu")
        mlp = (3 if gated else 2) * d * f
        moe = self.num_experts * 3 * d * f + d * self.num_experts
        return total + n_attn * attn + n_ssm * ssm + n_mlp * mlp + n_moe * moe

    def active_param_count(self) -> int:
        """Active params per token (MoE: only routed experts)."""
        if self.num_experts == 0:
            return self.param_count()
        d, f = self.d_model, self.d_ff
        n_moe = sum(1 for _, fk in self.layer_list() if fk == "moe")
        full_moe = self.num_experts * 3 * d * f
        active_moe = self.experts_per_token * 3 * d * f
        return self.param_count() - n_moe * (full_moe - active_moe)

    def layer_list(self) -> list[tuple[str, str]]:
        plen = len(self.pattern)
        full = self.num_layers // plen
        rem = self.num_layers % plen
        return list(self.pattern) * full + list(self.pattern[:rem])


@dataclass(frozen=True)
class ShapeConfig:
    name: str
    seq_len: int
    global_batch: int
    kind: str  # train | prefill | decode


SHAPES = {
    "train_4k": ShapeConfig("train_4k", 4096, 256, "train"),
    "prefill_32k": ShapeConfig("prefill_32k", 32768, 32, "prefill"),
    "decode_32k": ShapeConfig("decode_32k", 32768, 128, "decode"),
    "long_500k": ShapeConfig("long_500k", 524288, 1, "decode"),
}


def cell_status(cfg: ModelConfig, shape_name: str) -> tuple[bool, str]:
    """(runnable, reason). The 7 skips of DESIGN.md §4 are decided here."""
    shape = SHAPES[shape_name]
    if shape.kind == "decode" and not cfg.causal:
        return False, "encoder-only arch has no decode step"
    if shape_name == "long_500k" and not cfg.long_ok:
        return False, "pure full-attention arch; 500k decode cache is not sub-quadratic-serviceable"
    return True, ""


def runnable_cells(cfg: ModelConfig) -> list[str]:
    return [s for s in SHAPES if cell_status(cfg, s)[0]]
