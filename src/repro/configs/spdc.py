"""The paper's own workload config: SPDC secure determinant outsourcing.

Not an LM — this configures the Parallelize stage (matrix size, server
count, cipher mode, verification method) for benchmarks, examples, and the
SPDC dry-run cell.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class SPDCConfig:
    name: str = "spdc"
    matrix_n: int = 4096
    num_servers: int = 16
    mode: str = "ewd"  # ewd | ewm
    method: str = "q3"  # q1 | q2 | q3
    lambda1: int = 128
    lambda2: int = 128
    dtype: str = "float64"
    block: int = 256  # per-server blocked-LU tile
    # fault tolerance (DESIGN.md §4): N+r standby servers provisioned for
    # localized-shard re-dispatch, whether the client heals rejected
    # verdicts instead of re-outsourcing, and the straggler policy (rounds
    # a server may run late before its shard is re-dispatched; None waits).
    standby: int = 0
    recover: bool = False
    straggler_deadline: int | None = None

    def protocol_kwargs(self) -> dict:
        """Keyword arguments for core.protocol.outsource_determinant —
        the bridge that keeps these fields from drifting away from the
        protocol's actual signature (exercised in tests/test_recovery.py)."""
        return dict(
            lambda1=self.lambda1,
            lambda2=self.lambda2,
            mode=self.mode,
            method=self.method,
            recover=self.recover,
            standby=self.standby,
            straggler_deadline=self.straggler_deadline,
        )


SPDC_DEFAULT = SPDCConfig()
SPDC_EDGE_SMALL = SPDCConfig(name="spdc-edge-small", matrix_n=512, num_servers=4)
SPDC_POD = SPDCConfig(name="spdc-pod", matrix_n=8192, num_servers=16)
#: untrusted-edge profile: assume misbehavior, heal in place (N+2 spares)
SPDC_EDGE_HARDENED = SPDCConfig(
    name="spdc-edge-hardened", matrix_n=512, num_servers=4,
    standby=2, recover=True, straggler_deadline=8,
)
