"""The paper's own workload config: SPDC secure determinant outsourcing.

Not an LM — this configures the Parallelize stage (matrix size, server
count, cipher mode, verification method) for benchmarks, examples, and the
SPDC dry-run cell.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class RatelessConfig:
    """Knobs of the rateless dispatch layer (distrib.rateless).

    The scheduler streams strip tasks to whichever workers are free and
    completes when enough VERIFIED strips arrived — so there is no
    deadline to tune; these knobs shape how hard it leans on a degraded
    fleet, not whether it finishes.

    overdecompose: strips per matrix = overdecompose × num_servers (the
        paper's F > N rateless factor; 2 doubles the strips so a fast
        worker can absorb a slow one's share strip-by-strip).
    request_timeout_s: per-request wall-clock deadline handed to the
        transport (None = the transport's own default). A miss counts as
        a failure against the worker and the strip is re-streamed.
    max_attempts: dispatch attempts per strip before the client computes
        it inline (the degradation ladder's last rung — the session
        answers even with the whole fleet dark).
    backoff_base_s / backoff_max_s / backoff_jitter: exponential backoff
        between a worker's consecutive failures — base·2^(k−1) capped at
        max, ±jitter fraction drawn deterministically from the dispatch
        sub-seed (reproducible runs, no thundering herd).
    quarantine_after: consecutive failures (or ONE tamper) that bench a
        worker; it re-admits only by passing a probation probe — a
        re-issue of an already-verified strip checked against the known
        answer.
    probation_cooldown_s: how long a quarantined worker sits out before
        the scheduler spends a probe on it.
    ewma_alpha: weight of the newest latency sample in the per-worker
        EWMA the work-stealing assignment ranks workers by.
    min_live: fleet floor — fewer live workers than this flips the
        session to inline completion of the remaining strips.
    lanes: independent dispatch lanes for BATCHED sessions (each lane
        owns a contiguous slice of the batch and its own sequential
        strip chain, so lanes are what actually run concurrently).
        None = min(batch, fleet size); single matrices always run 1 lane.
    """

    overdecompose: int = 2
    request_timeout_s: float | None = 30.0
    max_attempts: int = 4
    backoff_base_s: float = 0.05
    backoff_max_s: float = 2.0
    backoff_jitter: float = 0.25
    quarantine_after: int = 2
    probation_cooldown_s: float = 0.5
    ewma_alpha: float = 0.5
    min_live: int = 1
    lanes: int | None = None

    def __post_init__(self):
        if self.overdecompose < 1:
            raise ValueError("overdecompose must be >= 1")
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.quarantine_after < 1:
            raise ValueError("quarantine_after must be >= 1")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if self.min_live < 0:
            raise ValueError("min_live must be >= 0")


RATELESS_DEFAULT = RatelessConfig()


@dataclass(frozen=True)
class SPDCConfig:
    name: str = "spdc"
    matrix_n: int = 4096
    num_servers: int = 16
    mode: str = "ewd"  # ewd | ewm
    method: str = "q3"  # q1 | q2 | q3
    lambda1: int = 128
    lambda2: int = 128
    dtype: str = "float64"
    # precision growth controls (DESIGN.md §6): None = the protocol's
    # dtype-keyed auto rule (on for sub-f64 compute, off for float64)
    growth_safe: bool | None = None
    equilibrate: bool | None = None
    block: int = 256  # per-server blocked-LU tile
    # fault tolerance (DESIGN.md §4): N+r standby servers provisioned for
    # localized-shard re-dispatch, whether the client heals rejected
    # verdicts instead of re-outsourcing, and the straggler policy (rounds
    # a server may run late before its shard is re-dispatched; None waits).
    standby: int = 0
    recover: bool = False
    straggler_deadline: int | None = None
    # execution boundary of the Parallelize stage (DESIGN.md §7/§9): a
    # name — "inline" (fused fast path) | "shardmap" | "threadpool" |
    # "multiprocess" (spawned workers, wire-codec messages) | "socket"
    # (warm worker daemons over TCP/UDS) — or a repro.api.TransportConfig
    # (declarative: name + addresses + timeout; frozen/hashable, so this
    # config stays hashable). Resolved by repro.api.resolve_transport.
    transport: object = "inline"
    # rateless straggler-adaptive dispatch (DESIGN.md §8): over-decompose
    # into F > N strips and stream them to whichever workers are free —
    # True uses RATELESS_DEFAULT knobs. Replaces straggler_deadline
    # (which a rateless session ignores: slow servers just do less).
    rateless: bool = False

    def protocol_kwargs(self) -> dict:
        """Keyword arguments for core.protocol.outsource_determinant —
        the bridge that keeps these fields from drifting away from the
        protocol's actual signature. Emits the FULL keyword set the config
        models; a reflection test (tests/test_api.py) asserts every key
        stays a real `outsource_determinant` parameter."""
        return dict(
            lambda1=self.lambda1,
            lambda2=self.lambda2,
            mode=self.mode,
            method=self.method,
            recover=self.recover,
            standby=self.standby,
            straggler_deadline=self.straggler_deadline,
            dtype=self.dtype,
            growth_safe=self.growth_safe,
            equilibrate=self.equilibrate,
            transport=self.transport,
            rateless=self.rateless,
        )


SPDC_DEFAULT = SPDCConfig()
SPDC_EDGE_SMALL = SPDCConfig(name="spdc-edge-small", matrix_n=512, num_servers=4)
SPDC_POD = SPDCConfig(name="spdc-pod", matrix_n=8192, num_servers=16)
#: untrusted-edge profile: assume misbehavior, heal in place (N+2 spares)
SPDC_EDGE_HARDENED = SPDCConfig(
    name="spdc-edge-hardened", matrix_n=512, num_servers=4,
    standby=2, recover=True, straggler_deadline=8,
)
#: accelerator/edge precision profile: float32 compute end-to-end — the
#: only dtype real TPUs have, and ~2× the dets/sec (and half the wire
#: bytes) of f64 everywhere else. The protocol auto-enables the
#: growth-safe relayout + equilibration (DESIGN.md §6) and the ε(N)
#: thresholds read the f32 unit roundoff.
SPDC_EDGE_F32 = SPDCConfig(
    name="spdc-edge-f32", matrix_n=512, num_servers=4, dtype="float32",
)
#: role-split transports (DESIGN.md §7): same protocol, real execution
#: boundaries. threadpool = in-process workers with message dispatch;
#: multiprocess = spawned worker processes, ShardTask/ShardResult bytes
#: crossing an OS pipe — the closest profile to real remote edge servers.
SPDC_EDGE_THREADS = SPDCConfig(
    name="spdc-edge-threads", matrix_n=512, num_servers=4,
    transport="threadpool",
)
SPDC_EDGE_MP = SPDCConfig(
    name="spdc-edge-mp", matrix_n=256, num_servers=4,
    transport="multiprocess", standby=1, recover=True,
)
#: heterogeneous-fleet profile (DESIGN.md §8): rateless dispatch over
#: message workers — no straggler_deadline to tune, slow servers just
#: complete fewer strips, tamperers get quarantined mid-session.
SPDC_EDGE_RATELESS = SPDCConfig(
    name="spdc-edge-rateless", matrix_n=256, num_servers=4,
    transport="threadpool", recover=True, rateless=True,
)
#: networked-fleet profile (DESIGN.md §9): warm worker daemons over
#: TCP/UDS sockets — jit caches survive across sessions and client
#: restarts. The bare "socket" name self-hosts local UDS daemons; point
#: at a real fleet with transport=TransportConfig("socket",
#: addresses=("tcp://host:port", ...)).
SPDC_EDGE_SOCKET = SPDCConfig(
    name="spdc-edge-socket", matrix_n=256, num_servers=4,
    transport="socket", standby=1, recover=True,
)


@dataclass(frozen=True)
class AdmissionConfig:
    """Per-tenant admission control for the gateway (DESIGN.md §10.1).

    Tenancy is an ACCOUNTING dimension, not a bucketing one: all tenants'
    requests still coalesce into shared sweeps; what is per-tenant is the
    right to enter the queue. Both knobs default to off (None) so a
    gateway without multi-tenant policy behaves exactly as before.

    rate_per_sec: token-bucket refill rate per tenant (None = unlimited).
    burst: max banked tokens (None = max(1, rate_per_sec) — one second of
        headroom; a fresh tenant may burst this many at once).
    max_pending_per_tenant: pending-request quota per tenant (None =
        unlimited). Exceeding either raises a typed AdmissionRejected at
        submit time — distinct from GatewayOverloaded, which is the
        gateway-wide capacity door.
    """

    rate_per_sec: float | None = None
    burst: float | None = None
    max_pending_per_tenant: int | None = None

    def __post_init__(self):
        if self.rate_per_sec is not None and self.rate_per_sec <= 0:
            raise ValueError("rate_per_sec must be > 0 (or None for off)")
        if self.burst is not None and self.burst <= 0:
            raise ValueError("burst must be > 0 (or None for auto)")
        if (self.max_pending_per_tenant is not None
                and self.max_pending_per_tenant < 1):
            raise ValueError("max_pending_per_tenant must be >= 1 (or None)")


ADMISSION_OFF = AdmissionConfig()


@dataclass(frozen=True)
class BreakerConfig:
    """Per-bucket circuit breaker (DESIGN.md §10.2).

    failure_threshold: consecutive sweep failures (the sweep RAISED) that
        trip the breaker.
    max_unverified_rate: EWMA unverified-fraction above which the breaker
        trips even though sweeps complete (None = failures only). A
        bucket that keeps producing rejected verdicts burns device time
        for answers nobody can accept — operationally a failure.
    unverified_alpha / min_samples: EWMA weight of the newest flush and
        the flush count before the unverified signal may trip.
    cooldown_base_s / cooldown_max_s / probe_jitter: open-state cooldown
        base·2^(opens−1) capped at max, ±jitter fraction drawn
        deterministically from the bucket identity (no thundering herd,
        exact probe times on the virtual clock).
    on_open: what an open breaker does to NEW submissions — "fastfail"
        raises a typed BreakerOpen with a retry-after hint; "direct"
        detours them to the un-coalesced direct path (degraded but
        served, and isolated from the poisoned compiled sweep).
    enabled: master switch (False restores pre-breaker behavior).
    """

    failure_threshold: int = 3
    max_unverified_rate: float | None = 0.5
    unverified_alpha: float = 0.4
    min_samples: int = 4
    cooldown_base_s: float = 1.0
    cooldown_max_s: float = 60.0
    probe_jitter: float = 0.1
    on_open: str = "fastfail"
    enabled: bool = True

    def __post_init__(self):
        if self.failure_threshold < 1:
            raise ValueError("failure_threshold must be >= 1")
        if self.max_unverified_rate is not None and not (
                0.0 < self.max_unverified_rate <= 1.0):
            raise ValueError("max_unverified_rate must be in (0, 1] or None")
        if not 0.0 < self.unverified_alpha <= 1.0:
            raise ValueError("unverified_alpha must be in (0, 1]")
        if self.cooldown_base_s <= 0 or self.cooldown_max_s < self.cooldown_base_s:
            raise ValueError("need 0 < cooldown_base_s <= cooldown_max_s")
        if not 0.0 <= self.probe_jitter < 1.0:
            raise ValueError("probe_jitter must be in [0, 1)")
        if self.on_open not in ("fastfail", "direct"):
            raise ValueError("on_open must be 'fastfail' or 'direct'")


BREAKER_DEFAULT = BreakerConfig()
BREAKER_OFF = BreakerConfig(enabled=False)


@dataclass(frozen=True)
class CacheConfig:
    """Idempotency-keyed result cache (DESIGN.md §10.3).

    det is deterministic given (matrix bytes, security tuple), so a
    content-hash cache-aside turns repeated matrices into O(hash) hits.
    The key covers the full BucketKey (every protocol/security/dtype/
    transport field) plus the tenant, so a hit never crosses configs or
    tenants. Only verified results are stored.

    enabled: master switch.
    max_entries: LRU bound on cached results.
    single_flight: coalesce concurrent IDENTICAL submissions — followers
        ride the leader's sweep instead of enqueueing a duplicate, and
        each still receives its own result.
    """

    enabled: bool = True
    max_entries: int = 256
    single_flight: bool = True

    def __post_init__(self):
        if self.max_entries < 1:
            raise ValueError("max_entries must be >= 1")


CACHE_DEFAULT = CacheConfig()
CACHE_OFF = CacheConfig(enabled=False)


@dataclass(frozen=True)
class SPDCGatewayConfig:
    """Micro-batching gateway presets (DESIGN.md §5) — consumed by
    repro.serve.spdc_gateway.SPDCGateway.

    buckets: the padded sizes n' requests are coalesced at. A request of
        raw size n lands in the smallest bucket >= n; each bucket flushes
        as ONE mixed-size protocol sweep. Every bucket must satisfy
        n' % num_servers == 0 and n' / num_servers > 1.
    max_batch: flush a bucket the moment it holds this many requests.
    max_wait_us: flush a partial bucket once its oldest request has waited
        this long (latency bound for light traffic).
    max_pending: backpressure — submissions beyond this many queued
        requests raise GatewayOverloaded instead of growing the queue
        without bound.
    pad_batches: round every flushed batch up to the next power-of-two
        (≤ max_batch) with discarded dummy matrices, so a bucket only ever
        compiles log2(max_batch)+1 sweep shapes instead of one per
        partial-flush size — a timeout flush of 3 requests must not pay a
        fresh XLA compile in its latency.
    warmup_batches: batch sizes pre-compiled per bucket by
        SPDCGateway.warmup() so the first live flush doesn't pay jit cost
        (empty = the pad_batches shape set).
    spdc: the protocol parameters (server count, cipher mode, verification
        method, recovery policy) every bucket runs with by default;
        per-request overrides open extra buckets.
    admission: per-tenant rate limiting + pending quotas (DESIGN.md
        §10.1; defaults to off — single-tenant gateways are unchanged).
    breaker: per-bucket circuit breaker (DESIGN.md §10.2; on by default
        with a 3-consecutive-failure trip).
    cache: idempotency-keyed result cache + single-flight dedup
        (DESIGN.md §10.3; on by default, 256-entry LRU).
    """

    name: str = "spdc-gateway"
    buckets: tuple[int, ...] = (64, 128, 256, 512, 1024)
    max_batch: int = 32
    max_wait_us: float = 2_000.0
    max_pending: int = 4096
    pad_batches: bool = True
    warmup_batches: tuple[int, ...] = ()
    spdc: SPDCConfig = SPDC_EDGE_SMALL
    admission: AdmissionConfig = ADMISSION_OFF
    breaker: BreakerConfig = BREAKER_DEFAULT
    cache: CacheConfig = CACHE_DEFAULT


SPDC_GATEWAY_DEFAULT = SPDCGatewayConfig()
#: latency-biased: small batches, tight flush deadline
SPDC_GATEWAY_LOWLAT = SPDCGatewayConfig(
    name="spdc-gateway-lowlat", max_batch=8, max_wait_us=250.0,
)
#: throughput-biased: deep batches, generous coalescing window
SPDC_GATEWAY_BULK = SPDCGatewayConfig(
    name="spdc-gateway-bulk", max_batch=128, max_wait_us=20_000.0,
    max_pending=16384,
)
#: untrusted-edge serving: every bucket sweep heals rejected verdicts in
#: place with N+2 standby servers (DESIGN.md §4)
SPDC_GATEWAY_HARDENED = SPDCGatewayConfig(
    name="spdc-gateway-hardened", spdc=SPDC_EDGE_HARDENED,
)
#: float32 serving: every default bucket sweeps in f32 (f64 clients can
#: still opt up per request via submit(dtype="float64"))
SPDC_GATEWAY_F32 = SPDCGatewayConfig(
    name="spdc-gateway-f32", spdc=SPDC_EDGE_F32,
)
#: gateway over the threadpool transport: every bucket sweep dispatches
#: ShardTasks to in-process edge workers (per-request transport overrides
#: can still opt back to "inline")
SPDC_GATEWAY_THREADS = SPDCGatewayConfig(
    name="spdc-gateway-threads", spdc=SPDC_EDGE_THREADS,
)
#: gateway over warm socket daemons (DESIGN.md §9): bucket sweeps stream
#: ShardTasks to persistent worker processes whose jit caches outlive any
#: single gateway — the deployment shape for a long-lived edge fleet.
SPDC_GATEWAY_SOCKET = SPDCGatewayConfig(
    name="spdc-gateway-socket", spdc=SPDC_EDGE_SOCKET,
)
#: public-facing deployment profile (DESIGN.md §10): per-tenant admission
#: control ON (100 req/s, 256-pending quota per tenant), breaker + cache
#: at their defaults — the preset serve_spdc --prod uses, and the shape
#: ROADMAP item 3's "millions of users" story deploys.
SPDC_GATEWAY_PROD = SPDCGatewayConfig(
    name="spdc-gateway-prod",
    admission=AdmissionConfig(rate_per_sec=100.0, burst=200.0,
                              max_pending_per_tenant=256),
)
