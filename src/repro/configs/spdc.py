"""The paper's own workload config: SPDC secure determinant outsourcing.

Not an LM — this configures the Parallelize stage (matrix size, server
count, cipher mode, verification method) for benchmarks, examples, and the
SPDC dry-run cell.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class SPDCConfig:
    name: str = "spdc"
    matrix_n: int = 4096
    num_servers: int = 16
    mode: str = "ewd"  # ewd | ewm
    method: str = "q3"  # q1 | q2 | q3
    lambda1: int = 128
    lambda2: int = 128
    dtype: str = "float64"
    block: int = 256  # per-server blocked-LU tile


SPDC_DEFAULT = SPDCConfig()
SPDC_EDGE_SMALL = SPDCConfig(name="spdc-edge-small", matrix_n=512, num_servers=4)
SPDC_POD = SPDCConfig(name="spdc-pod", matrix_n=8192, num_servers=16)
