"""The paper's own workload config: SPDC secure determinant outsourcing.

Not an LM — this configures the Parallelize stage (matrix size, server
count, cipher mode, verification method) for benchmarks, examples, and the
SPDC dry-run cell.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class RatelessConfig:
    """Knobs of the rateless dispatch layer (distrib.rateless).

    The scheduler streams strip tasks to whichever workers are free and
    completes when enough VERIFIED strips arrived — so there is no
    deadline to tune; these knobs shape how hard it leans on a degraded
    fleet, not whether it finishes.

    overdecompose: strips per matrix = overdecompose × num_servers (the
        paper's F > N rateless factor; 2 doubles the strips so a fast
        worker can absorb a slow one's share strip-by-strip).
    request_timeout_s: per-request wall-clock deadline handed to the
        transport (None = the transport's own default). A miss counts as
        a failure against the worker and the strip is re-streamed.
    max_attempts: dispatch attempts per strip before the client computes
        it inline (the degradation ladder's last rung — the session
        answers even with the whole fleet dark).
    backoff_base_s / backoff_max_s / backoff_jitter: exponential backoff
        between a worker's consecutive failures — base·2^(k−1) capped at
        max, ±jitter fraction drawn deterministically from the dispatch
        sub-seed (reproducible runs, no thundering herd).
    quarantine_after: consecutive failures (or ONE tamper) that bench a
        worker; it re-admits only by passing a probation probe — a
        re-issue of an already-verified strip checked against the known
        answer.
    probation_cooldown_s: how long a quarantined worker sits out before
        the scheduler spends a probe on it.
    ewma_alpha: weight of the newest latency sample in the per-worker
        EWMA the work-stealing assignment ranks workers by.
    min_live: fleet floor — fewer live workers than this flips the
        session to inline completion of the remaining strips.
    lanes: independent dispatch lanes for BATCHED sessions (each lane
        owns a contiguous slice of the batch and its own sequential
        strip chain, so lanes are what actually run concurrently).
        None = min(batch, fleet size); single matrices always run 1 lane.
    """

    overdecompose: int = 2
    request_timeout_s: float | None = 30.0
    max_attempts: int = 4
    backoff_base_s: float = 0.05
    backoff_max_s: float = 2.0
    backoff_jitter: float = 0.25
    quarantine_after: int = 2
    probation_cooldown_s: float = 0.5
    ewma_alpha: float = 0.5
    min_live: int = 1
    lanes: int | None = None

    def __post_init__(self):
        if self.overdecompose < 1:
            raise ValueError("overdecompose must be >= 1")
        if self.max_attempts < 1:
            raise ValueError("max_attempts must be >= 1")
        if self.quarantine_after < 1:
            raise ValueError("quarantine_after must be >= 1")
        if not 0.0 < self.ewma_alpha <= 1.0:
            raise ValueError("ewma_alpha must be in (0, 1]")
        if self.min_live < 0:
            raise ValueError("min_live must be >= 0")


RATELESS_DEFAULT = RatelessConfig()


@dataclass(frozen=True)
class SPDCConfig:
    name: str = "spdc"
    matrix_n: int = 4096
    num_servers: int = 16
    mode: str = "ewd"  # ewd | ewm
    method: str = "q3"  # q1 | q2 | q3
    lambda1: int = 128
    lambda2: int = 128
    dtype: str = "float64"
    # precision growth controls (DESIGN.md §6): None = the protocol's
    # dtype-keyed auto rule (on for sub-f64 compute, off for float64)
    growth_safe: bool | None = None
    equilibrate: bool | None = None
    block: int = 256  # per-server blocked-LU tile
    # fault tolerance (DESIGN.md §4): N+r standby servers provisioned for
    # localized-shard re-dispatch, whether the client heals rejected
    # verdicts instead of re-outsourcing, and the straggler policy (rounds
    # a server may run late before its shard is re-dispatched; None waits).
    standby: int = 0
    recover: bool = False
    straggler_deadline: int | None = None
    # execution boundary of the Parallelize stage (DESIGN.md §7/§9): a
    # name — "inline" (fused fast path) | "shardmap" | "threadpool" |
    # "multiprocess" (spawned workers, wire-codec messages) | "socket"
    # (warm worker daemons over TCP/UDS) — or a repro.api.TransportConfig
    # (declarative: name + addresses + timeout; frozen/hashable, so this
    # config stays hashable). Resolved by repro.api.resolve_transport.
    transport: object = "inline"
    # rateless straggler-adaptive dispatch (DESIGN.md §8): over-decompose
    # into F > N strips and stream them to whichever workers are free —
    # True uses RATELESS_DEFAULT knobs. Replaces straggler_deadline
    # (which a rateless session ignores: slow servers just do less).
    rateless: bool = False

    def protocol_kwargs(self) -> dict:
        """Keyword arguments for core.protocol.outsource_determinant —
        the bridge that keeps these fields from drifting away from the
        protocol's actual signature. Emits the FULL keyword set the config
        models; a reflection test (tests/test_api.py) asserts every key
        stays a real `outsource_determinant` parameter."""
        return dict(
            lambda1=self.lambda1,
            lambda2=self.lambda2,
            mode=self.mode,
            method=self.method,
            recover=self.recover,
            standby=self.standby,
            straggler_deadline=self.straggler_deadline,
            dtype=self.dtype,
            growth_safe=self.growth_safe,
            equilibrate=self.equilibrate,
            transport=self.transport,
            rateless=self.rateless,
        )


SPDC_DEFAULT = SPDCConfig()
SPDC_EDGE_SMALL = SPDCConfig(name="spdc-edge-small", matrix_n=512, num_servers=4)
SPDC_POD = SPDCConfig(name="spdc-pod", matrix_n=8192, num_servers=16)
#: untrusted-edge profile: assume misbehavior, heal in place (N+2 spares)
SPDC_EDGE_HARDENED = SPDCConfig(
    name="spdc-edge-hardened", matrix_n=512, num_servers=4,
    standby=2, recover=True, straggler_deadline=8,
)
#: accelerator/edge precision profile: float32 compute end-to-end — the
#: only dtype real TPUs have, and ~2× the dets/sec (and half the wire
#: bytes) of f64 everywhere else. The protocol auto-enables the
#: growth-safe relayout + equilibration (DESIGN.md §6) and the ε(N)
#: thresholds read the f32 unit roundoff.
SPDC_EDGE_F32 = SPDCConfig(
    name="spdc-edge-f32", matrix_n=512, num_servers=4, dtype="float32",
)
#: role-split transports (DESIGN.md §7): same protocol, real execution
#: boundaries. threadpool = in-process workers with message dispatch;
#: multiprocess = spawned worker processes, ShardTask/ShardResult bytes
#: crossing an OS pipe — the closest profile to real remote edge servers.
SPDC_EDGE_THREADS = SPDCConfig(
    name="spdc-edge-threads", matrix_n=512, num_servers=4,
    transport="threadpool",
)
SPDC_EDGE_MP = SPDCConfig(
    name="spdc-edge-mp", matrix_n=256, num_servers=4,
    transport="multiprocess", standby=1, recover=True,
)
#: heterogeneous-fleet profile (DESIGN.md §8): rateless dispatch over
#: message workers — no straggler_deadline to tune, slow servers just
#: complete fewer strips, tamperers get quarantined mid-session.
SPDC_EDGE_RATELESS = SPDCConfig(
    name="spdc-edge-rateless", matrix_n=256, num_servers=4,
    transport="threadpool", recover=True, rateless=True,
)
#: networked-fleet profile (DESIGN.md §9): warm worker daemons over
#: TCP/UDS sockets — jit caches survive across sessions and client
#: restarts. The bare "socket" name self-hosts local UDS daemons; point
#: at a real fleet with transport=TransportConfig("socket",
#: addresses=("tcp://host:port", ...)).
SPDC_EDGE_SOCKET = SPDCConfig(
    name="spdc-edge-socket", matrix_n=256, num_servers=4,
    transport="socket", standby=1, recover=True,
)


@dataclass(frozen=True)
class SPDCGatewayConfig:
    """Micro-batching gateway presets (DESIGN.md §5) — consumed by
    repro.serve.spdc_gateway.SPDCGateway.

    buckets: the padded sizes n' requests are coalesced at. A request of
        raw size n lands in the smallest bucket >= n; each bucket flushes
        as ONE mixed-size protocol sweep. Every bucket must satisfy
        n' % num_servers == 0 and n' / num_servers > 1.
    max_batch: flush a bucket the moment it holds this many requests.
    max_wait_us: flush a partial bucket once its oldest request has waited
        this long (latency bound for light traffic).
    max_pending: backpressure — submissions beyond this many queued
        requests raise GatewayOverloaded instead of growing the queue
        without bound.
    pad_batches: round every flushed batch up to the next power-of-two
        (≤ max_batch) with discarded dummy matrices, so a bucket only ever
        compiles log2(max_batch)+1 sweep shapes instead of one per
        partial-flush size — a timeout flush of 3 requests must not pay a
        fresh XLA compile in its latency.
    warmup_batches: batch sizes pre-compiled per bucket by
        SPDCGateway.warmup() so the first live flush doesn't pay jit cost
        (empty = the pad_batches shape set).
    spdc: the protocol parameters (server count, cipher mode, verification
        method, recovery policy) every bucket runs with by default;
        per-request overrides open extra buckets.
    """

    name: str = "spdc-gateway"
    buckets: tuple[int, ...] = (64, 128, 256, 512, 1024)
    max_batch: int = 32
    max_wait_us: float = 2_000.0
    max_pending: int = 4096
    pad_batches: bool = True
    warmup_batches: tuple[int, ...] = ()
    spdc: SPDCConfig = SPDC_EDGE_SMALL


SPDC_GATEWAY_DEFAULT = SPDCGatewayConfig()
#: latency-biased: small batches, tight flush deadline
SPDC_GATEWAY_LOWLAT = SPDCGatewayConfig(
    name="spdc-gateway-lowlat", max_batch=8, max_wait_us=250.0,
)
#: throughput-biased: deep batches, generous coalescing window
SPDC_GATEWAY_BULK = SPDCGatewayConfig(
    name="spdc-gateway-bulk", max_batch=128, max_wait_us=20_000.0,
    max_pending=16384,
)
#: untrusted-edge serving: every bucket sweep heals rejected verdicts in
#: place with N+2 standby servers (DESIGN.md §4)
SPDC_GATEWAY_HARDENED = SPDCGatewayConfig(
    name="spdc-gateway-hardened", spdc=SPDC_EDGE_HARDENED,
)
#: float32 serving: every default bucket sweeps in f32 (f64 clients can
#: still opt up per request via submit(dtype="float64"))
SPDC_GATEWAY_F32 = SPDCGatewayConfig(
    name="spdc-gateway-f32", spdc=SPDC_EDGE_F32,
)
#: gateway over the threadpool transport: every bucket sweep dispatches
#: ShardTasks to in-process edge workers (per-request transport overrides
#: can still opt back to "inline")
SPDC_GATEWAY_THREADS = SPDCGatewayConfig(
    name="spdc-gateway-threads", spdc=SPDC_EDGE_THREADS,
)
#: gateway over warm socket daemons (DESIGN.md §9): bucket sweeps stream
#: ShardTasks to persistent worker processes whose jit caches outlive any
#: single gateway — the deployment shape for a long-lived edge fleet.
SPDC_GATEWAY_SOCKET = SPDCGatewayConfig(
    name="spdc-gateway-socket", spdc=SPDC_EDGE_SOCKET,
)
