"""The paper's own workload config: SPDC secure determinant outsourcing.

Not an LM — this configures the Parallelize stage (matrix size, server
count, cipher mode, verification method) for benchmarks, examples, and the
SPDC dry-run cell.
"""
from __future__ import annotations

from dataclasses import dataclass


@dataclass(frozen=True)
class SPDCConfig:
    name: str = "spdc"
    matrix_n: int = 4096
    num_servers: int = 16
    mode: str = "ewd"  # ewd | ewm
    method: str = "q3"  # q1 | q2 | q3
    lambda1: int = 128
    lambda2: int = 128
    dtype: str = "float64"
    # precision growth controls (DESIGN.md §6): None = the protocol's
    # dtype-keyed auto rule (on for sub-f64 compute, off for float64)
    growth_safe: bool | None = None
    equilibrate: bool | None = None
    block: int = 256  # per-server blocked-LU tile
    # fault tolerance (DESIGN.md §4): N+r standby servers provisioned for
    # localized-shard re-dispatch, whether the client heals rejected
    # verdicts instead of re-outsourcing, and the straggler policy (rounds
    # a server may run late before its shard is re-dispatched; None waits).
    standby: int = 0
    recover: bool = False
    straggler_deadline: int | None = None
    # execution boundary of the Parallelize stage (DESIGN.md §7):
    # "inline" (fused fast path) | "shardmap" | "threadpool" |
    # "multiprocess" (spawned workers, wire-codec messages)
    transport: str = "inline"

    def protocol_kwargs(self) -> dict:
        """Keyword arguments for core.protocol.outsource_determinant —
        the bridge that keeps these fields from drifting away from the
        protocol's actual signature. Emits the FULL keyword set the config
        models; a reflection test (tests/test_api.py) asserts every key
        stays a real `outsource_determinant` parameter."""
        return dict(
            lambda1=self.lambda1,
            lambda2=self.lambda2,
            mode=self.mode,
            method=self.method,
            recover=self.recover,
            standby=self.standby,
            straggler_deadline=self.straggler_deadline,
            dtype=self.dtype,
            growth_safe=self.growth_safe,
            equilibrate=self.equilibrate,
            transport=self.transport,
        )


SPDC_DEFAULT = SPDCConfig()
SPDC_EDGE_SMALL = SPDCConfig(name="spdc-edge-small", matrix_n=512, num_servers=4)
SPDC_POD = SPDCConfig(name="spdc-pod", matrix_n=8192, num_servers=16)
#: untrusted-edge profile: assume misbehavior, heal in place (N+2 spares)
SPDC_EDGE_HARDENED = SPDCConfig(
    name="spdc-edge-hardened", matrix_n=512, num_servers=4,
    standby=2, recover=True, straggler_deadline=8,
)
#: accelerator/edge precision profile: float32 compute end-to-end — the
#: only dtype real TPUs have, and ~2× the dets/sec (and half the wire
#: bytes) of f64 everywhere else. The protocol auto-enables the
#: growth-safe relayout + equilibration (DESIGN.md §6) and the ε(N)
#: thresholds read the f32 unit roundoff.
SPDC_EDGE_F32 = SPDCConfig(
    name="spdc-edge-f32", matrix_n=512, num_servers=4, dtype="float32",
)
#: role-split transports (DESIGN.md §7): same protocol, real execution
#: boundaries. threadpool = in-process workers with message dispatch;
#: multiprocess = spawned worker processes, ShardTask/ShardResult bytes
#: crossing an OS pipe — the closest profile to real remote edge servers.
SPDC_EDGE_THREADS = SPDCConfig(
    name="spdc-edge-threads", matrix_n=512, num_servers=4,
    transport="threadpool",
)
SPDC_EDGE_MP = SPDCConfig(
    name="spdc-edge-mp", matrix_n=256, num_servers=4,
    transport="multiprocess", standby=1, recover=True,
)


@dataclass(frozen=True)
class SPDCGatewayConfig:
    """Micro-batching gateway presets (DESIGN.md §5) — consumed by
    repro.serve.spdc_gateway.SPDCGateway.

    buckets: the padded sizes n' requests are coalesced at. A request of
        raw size n lands in the smallest bucket >= n; each bucket flushes
        as ONE mixed-size protocol sweep. Every bucket must satisfy
        n' % num_servers == 0 and n' / num_servers > 1.
    max_batch: flush a bucket the moment it holds this many requests.
    max_wait_us: flush a partial bucket once its oldest request has waited
        this long (latency bound for light traffic).
    max_pending: backpressure — submissions beyond this many queued
        requests raise GatewayOverloaded instead of growing the queue
        without bound.
    pad_batches: round every flushed batch up to the next power-of-two
        (≤ max_batch) with discarded dummy matrices, so a bucket only ever
        compiles log2(max_batch)+1 sweep shapes instead of one per
        partial-flush size — a timeout flush of 3 requests must not pay a
        fresh XLA compile in its latency.
    warmup_batches: batch sizes pre-compiled per bucket by
        SPDCGateway.warmup() so the first live flush doesn't pay jit cost
        (empty = the pad_batches shape set).
    spdc: the protocol parameters (server count, cipher mode, verification
        method, recovery policy) every bucket runs with by default;
        per-request overrides open extra buckets.
    """

    name: str = "spdc-gateway"
    buckets: tuple[int, ...] = (64, 128, 256, 512, 1024)
    max_batch: int = 32
    max_wait_us: float = 2_000.0
    max_pending: int = 4096
    pad_batches: bool = True
    warmup_batches: tuple[int, ...] = ()
    spdc: SPDCConfig = SPDC_EDGE_SMALL


SPDC_GATEWAY_DEFAULT = SPDCGatewayConfig()
#: latency-biased: small batches, tight flush deadline
SPDC_GATEWAY_LOWLAT = SPDCGatewayConfig(
    name="spdc-gateway-lowlat", max_batch=8, max_wait_us=250.0,
)
#: throughput-biased: deep batches, generous coalescing window
SPDC_GATEWAY_BULK = SPDCGatewayConfig(
    name="spdc-gateway-bulk", max_batch=128, max_wait_us=20_000.0,
    max_pending=16384,
)
#: untrusted-edge serving: every bucket sweep heals rejected verdicts in
#: place with N+2 standby servers (DESIGN.md §4)
SPDC_GATEWAY_HARDENED = SPDCGatewayConfig(
    name="spdc-gateway-hardened", spdc=SPDC_EDGE_HARDENED,
)
#: float32 serving: every default bucket sweeps in f32 (f64 clients can
#: still opt up per request via submit(dtype="float64"))
SPDC_GATEWAY_F32 = SPDCGatewayConfig(
    name="spdc-gateway-f32", spdc=SPDC_EDGE_F32,
)
#: gateway over the threadpool transport: every bucket sweep dispatches
#: ShardTasks to in-process edge workers (per-request transport overrides
#: can still opt back to "inline")
SPDC_GATEWAY_THREADS = SPDCGatewayConfig(
    name="spdc-gateway-threads", spdc=SPDC_EDGE_THREADS,
)
