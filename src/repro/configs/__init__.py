"""Config registry: get_config(name) for the 10 assigned archs, plus
reduced smoke variants (same family, tiny dims) for CPU tests."""
from __future__ import annotations

from dataclasses import replace

from .base import SHAPES, ModelConfig, ShapeConfig, cell_status, runnable_cells
from .gemma3_1b import GEMMA3_1B
from .gemma_2b import GEMMA_2B
from .granite_moe_1b_a400m import GRANITE_MOE_1B
from .hubert_xlarge import HUBERT_XLARGE
from .jamba_1_5_large_398b import JAMBA_1_5_LARGE
from .llama4_scout_17b_a16e import LLAMA4_SCOUT
from .mamba2_370m import MAMBA2_370M
from .nemotron_4_340b import NEMOTRON_4_340B
from .qwen2_vl_72b import QWEN2_VL_72B
from .spdc import (
    ADMISSION_OFF, BREAKER_DEFAULT, BREAKER_OFF, CACHE_DEFAULT, CACHE_OFF,
    RATELESS_DEFAULT, SPDC_DEFAULT, SPDC_EDGE_F32, SPDC_EDGE_HARDENED,
    SPDC_EDGE_MP, SPDC_EDGE_RATELESS, SPDC_EDGE_SMALL, SPDC_EDGE_SOCKET,
    SPDC_EDGE_THREADS, SPDC_GATEWAY_BULK, SPDC_GATEWAY_DEFAULT,
    SPDC_GATEWAY_F32, SPDC_GATEWAY_HARDENED, SPDC_GATEWAY_LOWLAT,
    SPDC_GATEWAY_PROD, SPDC_GATEWAY_SOCKET, SPDC_GATEWAY_THREADS, SPDC_POD,
    AdmissionConfig, BreakerConfig, CacheConfig, RatelessConfig,
    SPDCConfig, SPDCGatewayConfig,
)
from .tinyllama_1_1b import TINYLLAMA_1_1B

CONFIGS: dict[str, ModelConfig] = {
    c.name: c
    for c in [
        MAMBA2_370M, GEMMA_2B, NEMOTRON_4_340B, TINYLLAMA_1_1B, GEMMA3_1B,
        GRANITE_MOE_1B, LLAMA4_SCOUT, JAMBA_1_5_LARGE, QWEN2_VL_72B,
        HUBERT_XLARGE,
    ]
}


def get_config(name: str) -> ModelConfig:
    if name not in CONFIGS:
        raise KeyError(f"unknown arch {name!r}; known: {sorted(CONFIGS)}")
    return CONFIGS[name]


def smoke_config(name: str) -> ModelConfig:
    """Reduced same-family config: 1–2 periods, tiny dims, CPU-runnable."""
    cfg = get_config(name)
    plen = len(cfg.pattern)
    small = dict(
        num_layers=min(2 * plen + (1 if cfg.num_layers % plen else 0), cfg.num_layers),
        d_model=64,
        d_ff=128 if cfg.d_ff else 0,
        vocab_size=min(cfg.vocab_size, 256),
        activation_dtype="float32",
        params_dtype="float32",
        grad_accum=1,
    )
    if cfg.num_heads:
        small.update(num_heads=4, num_kv_heads=min(cfg.num_kv_heads, 2), head_dim=16)
    if cfg.num_experts:
        small.update(num_experts=4, experts_per_token=min(cfg.experts_per_token, 2))
    if cfg.ssm_heads:
        small.update(ssm_heads=4, ssm_head_dim=32, ssm_state=16, ssm_chunk=8)
    if cfg.window:
        small.update(window=16)
    return replace(cfg, name=cfg.name + "-smoke", **small)


__all__ = [
    "CONFIGS", "get_config", "smoke_config", "SHAPES", "ModelConfig",
    "ShapeConfig", "cell_status", "runnable_cells",
    "SPDCConfig", "SPDC_DEFAULT", "SPDC_EDGE_F32", "SPDC_EDGE_HARDENED",
    "SPDC_EDGE_MP", "SPDC_EDGE_RATELESS", "SPDC_EDGE_SMALL",
    "SPDC_EDGE_SOCKET", "SPDC_EDGE_THREADS", "SPDC_POD",
    "RatelessConfig", "RATELESS_DEFAULT",
    "SPDCGatewayConfig", "SPDC_GATEWAY_DEFAULT", "SPDC_GATEWAY_LOWLAT",
    "SPDC_GATEWAY_BULK", "SPDC_GATEWAY_HARDENED", "SPDC_GATEWAY_F32",
    "SPDC_GATEWAY_THREADS", "SPDC_GATEWAY_SOCKET", "SPDC_GATEWAY_PROD",
    "AdmissionConfig", "ADMISSION_OFF", "BreakerConfig", "BREAKER_DEFAULT",
    "BREAKER_OFF", "CacheConfig", "CACHE_DEFAULT", "CACHE_OFF",
]
