"""Architecture config: jamba-1.5-large-398b [hybrid] 1:7 + MoE. Auto-split from the assignment table."""
from .base import ModelConfig

# -- [ssm] SSD / state-space duality [arXiv:2405.21060] ----------------------
MAMBA2_370M = ModelConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    pattern=(("ssm", "none"),),
    rope_type="none",
    ssm_state=128, ssm_heads=32, ssm_head_dim=64, ssm_expand=2,
    long_ok=True,
    notes="attention-free; decode is O(1)/token via the SSM state",
)

# -- [dense] Gemma 2B: GeGLU, head_dim 256, MQA [arXiv:2403.08295] -----------
GEMMA_2B = ModelConfig(
    name="gemma-2b", family="dense",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000,
    pattern=(("attn_full", "mlp"),),
    mlp_type="geglu",
    notes="MQA (kv=1): KV replicated across model axis; 8 heads < 16-way "
          "model axis => sequence-parallel attention fallback",
)

# -- [dense] Nemotron-4 340B: GQA kv=8, squared-ReLU [arXiv:2402.16819] ------
NEMOTRON_4_340B = ModelConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8, head_dim=192,
    d_ff=73728, vocab_size=256000,
    pattern=(("attn_full", "mlp"),),
    mlp_type="relu2",
    optimizer_dtype="bfloat16", grad_accum=32,
    notes="bf16 optimizer state + 16-way grad accumulation to fit 340B "
          "training state in 256x16GB (DESIGN.md §5)",
)

# -- [dense] TinyLlama 1.1B: llama2 arch [arXiv:2401.02385] ------------------
TINYLLAMA_1_1B = ModelConfig(
    name="tinyllama-1.1b", family="dense",
    num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=64,
    d_ff=5632, vocab_size=32000,
    pattern=(("attn_full", "mlp"),),
    mlp_type="swiglu",
)

# -- [dense] Gemma3 1B: 5:1 local:global sliding window [hf] -----------------
GEMMA3_1B = ModelConfig(
    name="gemma3-1b", family="dense",
    num_layers=26, d_model=1152, num_heads=4, num_kv_heads=1, head_dim=256,
    d_ff=6912, vocab_size=262144,
    pattern=(("attn_sliding", "mlp"),) * 5 + (("attn_full", "mlp"),),
    mlp_type="geglu", window=1024, rope_theta=1e6,
    long_ok=True,
    notes="26 = 4 full periods of 6 + 2 remainder (sliding) layers; "
          "single rope_theta used for local+global",
)

# -- [moe] Granite 3.0 1B-A400M: 32e top-8 [hf:ibm-granite] ------------------
GRANITE_MOE_1B = ModelConfig(
    name="granite-moe-1b-a400m", family="moe",
    num_layers=24, d_model=1024, num_heads=16, num_kv_heads=8, head_dim=64,
    d_ff=512, vocab_size=49155,
    pattern=(("attn_full", "moe"),),
    mlp_type="swiglu", num_experts=32, experts_per_token=8,
)

# -- [moe] Llama4 Scout 17B-A16E: 16e top-1, chunked attention [hf] ----------
LLAMA4_SCOUT = ModelConfig(
    name="llama4-scout-17b-a16e", family="moe",
    num_layers=48, d_model=5120, num_heads=40, num_kv_heads=8, head_dim=128,
    d_ff=8192, vocab_size=202048,
    pattern=(("attn_chunked", "moe"),) * 3 + (("attn_full", "moe"),),
    mlp_type="swiglu", num_experts=16, experts_per_token=1,
    window=8192, rope_theta=5e5, long_ok=True, grad_accum=4,
    notes="3:1 chunked-local:global (iRoPE-style, chunk 8192) => long_500k "
          "runs; shared expert omitted (backbone scope); 40 heads % 16 != 0 "
          "=> sequence-parallel attention",
)

# -- [hybrid] Jamba 1.5 Large 398B: 1:7 attn:mamba + MoE [arXiv:2403.19887] --
JAMBA_1_5_LARGE = ModelConfig(
    name="jamba-1.5-large-398b", family="hybrid",
    num_layers=72, d_model=8192, num_heads=64, num_kv_heads=8, head_dim=128,
    d_ff=24576, vocab_size=65536,
    pattern=(
        ("attn_full", "mlp"), ("ssm", "moe"), ("ssm", "mlp"), ("ssm", "moe"),
        ("ssm", "mlp"), ("ssm", "moe"), ("ssm", "mlp"), ("ssm", "moe"),
    ),
    mlp_type="swiglu", rope_type="none",
    num_experts=16, experts_per_token=2,
    ssm_state=128, ssm_heads=256, ssm_head_dim=64, ssm_expand=2,
    long_ok=True, optimizer_dtype="bfloat16", grad_accum=32,
    notes="period of 8 = 1 attn + 7 mamba, MoE every 2nd layer; SSM is our "
          "SSD (Mamba2) primitive standing in for Jamba's Mamba-1 (DESIGN.md "
          "§4); attention layers carry no RoPE (position from SSM), as Jamba",
)
