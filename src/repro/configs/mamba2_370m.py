"""Architecture config: mamba2-370m [ssm] SSD. Auto-split from the assignment table."""
from .base import ModelConfig

# -- [ssm] SSD / state-space duality [arXiv:2405.21060] ----------------------
MAMBA2_370M = ModelConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    pattern=(("ssm", "none"),),
    rope_type="none",
    ssm_state=128, ssm_heads=32, ssm_head_dim=64, ssm_expand=2,
    long_ok=True,
    notes="attention-free; decode is O(1)/token via the SSM state",
)
