"""Architecture config: tinyllama-1.1b [dense] llama2-small. Auto-split from the assignment table."""
from .base import ModelConfig

# -- [ssm] SSD / state-space duality [arXiv:2405.21060] ----------------------
MAMBA2_370M = ModelConfig(
    name="mamba2-370m", family="ssm",
    num_layers=48, d_model=1024, num_heads=0, num_kv_heads=0, head_dim=0,
    d_ff=0, vocab_size=50280,
    pattern=(("ssm", "none"),),
    rope_type="none",
    ssm_state=128, ssm_heads=32, ssm_head_dim=64, ssm_expand=2,
    long_ok=True,
    notes="attention-free; decode is O(1)/token via the SSM state",
)

# -- [dense] Gemma 2B: GeGLU, head_dim 256, MQA [arXiv:2403.08295] -----------
GEMMA_2B = ModelConfig(
    name="gemma-2b", family="dense",
    num_layers=18, d_model=2048, num_heads=8, num_kv_heads=1, head_dim=256,
    d_ff=16384, vocab_size=256000,
    pattern=(("attn_full", "mlp"),),
    mlp_type="geglu",
    notes="MQA (kv=1): KV replicated across model axis; 8 heads < 16-way "
          "model axis => sequence-parallel attention fallback",
)

# -- [dense] Nemotron-4 340B: GQA kv=8, squared-ReLU [arXiv:2402.16819] ------
NEMOTRON_4_340B = ModelConfig(
    name="nemotron-4-340b", family="dense",
    num_layers=96, d_model=18432, num_heads=96, num_kv_heads=8, head_dim=192,
    d_ff=73728, vocab_size=256000,
    pattern=(("attn_full", "mlp"),),
    mlp_type="relu2",
    optimizer_dtype="bfloat16", grad_accum=16,
    notes="bf16 optimizer state + 16-way grad accumulation to fit 340B "
          "training state in 256x16GB (DESIGN.md §5)",
)

# -- [dense] TinyLlama 1.1B: llama2 arch [arXiv:2401.02385] ------------------
TINYLLAMA_1_1B = ModelConfig(
    name="tinyllama-1.1b", family="dense",
    num_layers=22, d_model=2048, num_heads=32, num_kv_heads=4, head_dim=64,
    d_ff=5632, vocab_size=32000,
    pattern=(("attn_full", "mlp"),),
    mlp_type="swiglu",
)
