"""Differentiable secure ops — `jax.custom_vjp` over the shared LU.

`secure_slogdet` / `secure_solve` / `secure_inv` are jit-compatible jax
functions whose FORWARD value comes from the outsourced protocol (a
`jax.pure_callback` into a `LinalgSession`) and whose VJPs route through
the SAME verified factors:

    ∂ log|det M| / ∂M = M⁻ᵀ          (one wide identity-RHS round, cached)
    z = M⁻¹b:   b̄ = M⁻ᵀz̄            (one masked adjoint round)
                M̄ = −b̄ · zᵀ          (client-side outer product)
    Y = M⁻¹:    M̄ = −Yᵀ·Ȳ·Yᵀ        (client-side, no extra round)

so a gradient step through slogdet + solve costs ONE factorization plus
a handful of O(n²)-client triangular-solve rounds — and nothing new
crosses the trust boundary in the backward pass: the adjoint rounds ship
the same blinded/public RHS shapes the forward ops do (linalg.session).

Sessions are cached per matrix VALUE (SHA-256 of bytes ‖ shape ‖ dtype)
on a `SecureLinalg` context, which is how the forward slogdet, the
forward solve, and both backward passes of one training step land on a
single factorization.  The callback pattern is sound because the
protocol is deterministic in the matrix bytes: seeds, keys, masks, and
probes all derive from SHA-256 of the plaintext, so re-execution under
jit replay returns bit-identical values.
"""
from __future__ import annotations

import concurrent.futures
import functools
import hashlib

import jax
import jax.numpy as jnp
import numpy as np

from .session import LinalgSession

__all__ = [
    "SecureLinalg", "default_linalg",
    "secure_slogdet", "secure_solve", "secure_inv",
]


class SecureLinalg:
    """Session cache + protocol configuration for the differentiable ops.

    One context = one fleet configuration (num_servers, transport,
    client knobs).  `session_for` returns the LinalgSession for a matrix
    value, opening one on first sight — every op and every VJP that sees
    the same bytes shares it, so `session.factorizations` stays 1 across
    a whole gradient step.
    """

    def __init__(self, num_servers: int = 2, *, transport=None,
                 max_sessions: int = 8, **session_kwargs):
        _disable_cpu_async_dispatch()
        self.num_servers = num_servers
        self.transport = transport
        self.session_kwargs = session_kwargs
        self.max_sessions = max_sessions
        self._sessions: dict = {}

    def session_for(self, a: np.ndarray) -> LinalgSession:
        a = np.ascontiguousarray(a)
        key = (hashlib.sha256(a.tobytes()).digest(), a.shape, str(a.dtype))
        s = self._sessions.get(key)
        if s is None:
            s = LinalgSession(a, self.num_servers,
                              transport=self.transport,
                              **self.session_kwargs)
            self._sessions[key] = s
            while len(self._sessions) > self.max_sessions:
                # dicts iterate in insertion order: evict the oldest
                self._sessions.pop(next(iter(self._sessions)))
        return s

    def clear(self) -> None:
        self._sessions.clear()


def _disable_cpu_async_dispatch() -> None:
    """Nested-dispatch deadlock guard, applied at import and per context.

    XLA:CPU's async dispatch runs expensive jitted programs on a single
    dispatch queue. A pure_callback inside such a program re-enters jax
    (the protocol's cipher/sweep/verify jits) and blocks on the result —
    which queues behind the very program waiting on the callback. Cheap
    outer graphs dodge this by executing inline, which is why the hang
    only shows once the operand has real in-graph producers (e.g. a
    kernel matrix built from hyperparameters). Synchronous dispatch makes
    re-entry safe at a small dispatch/compute overlap cost.

    The option is read ONCE, when the CPU client is created, so this must
    run before the first jax dispatch of the process — importing
    `repro.linalg` does it, hence the module-level call below. If the
    backend already exists the update is a silent no-op upstream, so warn
    loudly instead of deadlocking quietly later.
    """
    # the option is registered as a Flag, not a State: jax.config.update
    # accepts it but plain attribute reads raise AttributeError, so the
    # idempotence check must go through the holder table
    name = "jax_cpu_enable_async_dispatch"
    current = getattr(jax.config, name, None)
    if current is None:
        try:
            current = jax.config._value_holders[name].value
        except (AttributeError, KeyError):
            return  # option absent on this jax version
    if not current:
        return  # already off (this guard earlier, or the user)
    jax.config.update(name, False)
    try:
        import jax._src.xla_bridge as _xb

        late = bool(_xb._backends)
    except Exception:
        late = False
    if late:
        import warnings

        warnings.warn(
            "repro.linalg was imported after jax initialized its CPU "
            "backend; jax_cpu_enable_async_dispatch cannot take effect, "
            "and jit-compiled secure ops may deadlock on nested "
            "dispatch. Import repro.linalg first (or start the process "
            "with JAX_CPU_ENABLE_ASYNC_DISPATCH=0).",
            RuntimeWarning,
            stacklevel=3,
        )


_disable_cpu_async_dispatch()

_default: SecureLinalg | None = None


def default_linalg() -> SecureLinalg:
    """The module-default context (2 inline servers), built lazily."""
    global _default
    if _default is None:
        _default = SecureLinalg()
    return _default


def _np(x):
    return np.asarray(x)


#: Every callback body hops to this single plain Python thread. XLA may
#: invoke pure_callbacks from several of its own threads at once (fwd and
#: bwd callbacks of one step, or steps racing across user threads); the
#: one-worker hop serializes them onto the unsynchronized session cache
#: and keeps the protocol's transports single-threaded, as every other
#: client entry point does. (It does NOT fix the nested-dispatch
#: deadlock — see _disable_cpu_async_dispatch for that.)
_HOST_POOL = concurrent.futures.ThreadPoolExecutor(
    max_workers=1, thread_name_prefix="repro-linalg-host"
)


def _on_host_thread(fn):
    @functools.wraps(fn)
    def wrapper(*args):
        return _HOST_POOL.submit(fn, *args).result()

    return wrapper


# -- slogdet ----------------------------------------------------------------

def _slogdet_impl(ctx, a):
    @_on_host_thread
    def cb(a_np):
        s = ctx.session_for(_np(a_np))
        sign, logabs = s.slogdet()
        dt = _np(a_np).dtype
        return np.asarray(sign, dtype=dt), np.asarray(logabs, dtype=dt)

    out_shape = (jax.ShapeDtypeStruct((), a.dtype),
                 jax.ShapeDtypeStruct((), a.dtype))
    return jax.pure_callback(cb, out_shape, a)


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _slogdet(ctx, a):
    return _slogdet_impl(ctx, a)


def _slogdet_fwd(ctx, a):
    return _slogdet_impl(ctx, a), a


def _slogdet_bwd(ctx, a, ct):
    _, g_logabs = ct  # sign is locally constant, its cotangent drops

    @_on_host_thread
    def cb(a_np, g_np):
        s = ctx.session_for(_np(a_np))
        return (_np(g_np) * s.inv(transpose=True)).astype(_np(a_np).dtype)

    abar = jax.pure_callback(
        cb, jax.ShapeDtypeStruct(a.shape, a.dtype), a, g_logabs
    )
    return (abar,)


_slogdet.defvjp(_slogdet_fwd, _slogdet_bwd)


def secure_slogdet(a, *, linalg: SecureLinalg | None = None):
    """(sign, log|det a|) via the outsourced protocol; differentiable.

    Drop-in for `jnp.linalg.slogdet` on one (n, n) matrix.  The gradient
    of log|det| is M⁻ᵀ, computed through the session's shared verified
    factors — no fresh factorization, no new plaintext on the wire.
    """
    ctx = linalg if linalg is not None else default_linalg()
    a = jnp.asarray(a)
    if a.ndim != 2 or a.shape[0] != a.shape[1]:
        raise ValueError(f"secure_slogdet needs a square matrix, got "
                         f"{a.shape}")
    return _slogdet(ctx, a)


# -- solve ------------------------------------------------------------------

def _solve_impl(ctx, a, b):
    @_on_host_thread
    def cb(a_np, b_np):
        s = ctx.session_for(_np(a_np))
        return s.solve(_np(b_np)).astype(_np(b_np).dtype)

    return jax.pure_callback(
        cb, jax.ShapeDtypeStruct(b.shape, b.dtype), a, b
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _solve(ctx, a, b):
    return _solve_impl(ctx, a, b)


def _solve_fwd(ctx, a, b):
    z = _solve_impl(ctx, a, b)
    return z, (a, z)


def _solve_bwd(ctx, res, zbar):
    a, z = res

    @_on_host_thread
    def cb(a_np, g_np):
        s = ctx.session_for(_np(a_np))
        return s.solve(_np(g_np), transpose=True).astype(_np(g_np).dtype)

    bbar = jax.pure_callback(
        cb, jax.ShapeDtypeStruct(zbar.shape, zbar.dtype), a, zbar
    )
    if z.ndim == 1:
        abar = -jnp.outer(bbar, z)
    else:
        abar = -bbar @ z.T
    return abar, bbar


_solve.defvjp(_solve_fwd, _solve_bwd)


def secure_solve(a, b, *, linalg: SecureLinalg | None = None):
    """a x = b through the session's shared verified LU; differentiable.

    Drop-in for `jnp.linalg.solve` with b of shape (n,) or (n, c).  The
    adjoint b̄ = a⁻ᵀz̄ is ONE extra masked triangular-solve round through
    the same factors; ā = −b̄ zᵀ needs no round at all.
    """
    ctx = linalg if linalg is not None else default_linalg()
    a, b = jnp.asarray(a), jnp.asarray(b)
    if a.ndim != 2 or a.shape[0] != a.shape[1]:
        raise ValueError(f"secure_solve needs a square matrix, got "
                         f"{a.shape}")
    if b.ndim not in (1, 2) or b.shape[0] != a.shape[0]:
        raise ValueError(
            f"rhs shape {b.shape} does not match matrix {a.shape}"
        )
    return _solve(ctx, a, b)


# -- inv --------------------------------------------------------------------

def _inv_impl(ctx, a):
    @_on_host_thread
    def cb(a_np):
        s = ctx.session_for(_np(a_np))
        return s.inv().astype(_np(a_np).dtype)

    return jax.pure_callback(
        cb, jax.ShapeDtypeStruct(a.shape, a.dtype), a
    )


@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _inv(ctx, a):
    return _inv_impl(ctx, a)


def _inv_fwd(ctx, a):
    y = _inv_impl(ctx, a)
    return y, y


def _inv_bwd(ctx, y, ybar):
    # d(A⁻¹) = −A⁻¹ dA A⁻¹  ⇒  Ā = −Yᵀ Ȳ Yᵀ: pure jax-land, the wide
    # round already ran (and is cached) in the forward pass
    return (-(y.T @ ybar @ y.T),)


_inv.defvjp(_inv_fwd, _inv_bwd)


def secure_inv(a, *, linalg: SecureLinalg | None = None):
    """inv(a) via one wide public-permutation-RHS round; differentiable."""
    ctx = linalg if linalg is not None else default_linalg()
    a = jnp.asarray(a)
    if a.ndim != 2 or a.shape[0] != a.shape[1]:
        raise ValueError(f"secure_inv needs a square matrix, got {a.shape}")
    return _inv(ctx, a)
