"""LinalgSession — many secure ops on ONE verified outsourced LU.

The paper outsources a determinant; everything else the client might
want from the same matrix (solve, inverse, the slogdet pair) is a pure
function of the SAME no-pivot factors of the augmented ciphertext
X' = [[X, 0], [R, I]].  This module grows an *op plan* around one
factorization (DESIGN.md §12): the first op pays the full SPDC protocol
(cipher → N-server LU → Authenticate → heal), every later op is an
O(n²)-client round of triangular solves THROUGH the already-verified
factors, dispatched to the fleet as `TriSolveTask` column chunks.

Math.  With EWD ciphering, B = V⁻¹M (V = diag(v)) and X = Rᵏ(B) where
R(A) = Aᵀ·J is one clockwise quarter-turn (J = exchange).  Writing
G = X⁻¹ — available through the factors because the border block
structure gives inv(X')[:n,:n] = X⁻¹ and inv(X'ᵀ)[:n,:n] = X⁻ᵀ — the
inverse of the UNROTATED ciphertext is case-by-case

    B⁻¹ = G        (k ≡ 0)      B⁻ᵀ = Gᵀ
    B⁻¹ = Gᵀ·J     (k ≡ 1)      B⁻ᵀ = J·G
    B⁻¹ = J·G·J    (k ≡ 2)      B⁻ᵀ = J·Gᵀ·J
    B⁻¹ = J·Gᵀ     (k ≡ 3)      B⁻ᵀ = G·J

(growth-safe odd rotations compose the flip, giving X = Bᵀ exactly:
B⁻¹ = Gᵀ, B⁻ᵀ = G).  Each case is ONE triangular-solve round — G or Gᵀ
applied to a (permuted) right-hand side — plus client-side row
reversals, and the client recovers M⁻¹w = B⁻¹(w/v) (EWD; ·v for EWM),
M⁻ᵀw = (B⁻ᵀw)/v, and inv(M) = B⁻¹/v[None, :].

Trust boundary.  The solve rounds never widen what the servers see:
l/u are material the fleet itself produced, inverse rounds ship only a
PUBLIC permutation RHS (I or J columns — the secret 1/v column scaling
happens client-side after the round), and secret right-hand sides pass
through the `blind_rhs` one-time-pad chokepoint — W = [z; 0] + X'·C
with C drawn from a mask lane of the session digest that never leaves
the client, so the reply is Y = X'⁻¹[z; 0] + C and unmasking is a
subtraction.  Verification is per-chunk and client-keyed: narrow
(masked) rounds check the FULL residual ‖A·Y − W‖/‖W‖ against the
client-held X'; wide (inverse) rounds use a Freivalds probe drawn from a
secret probe lane — fresh per round, chunk, AND attempt, so a server
cannot precompute against it (the adaptive-attack fix of
core.inverse).  Failed chunks heal through
`distrib.recovery.recover_solve` — re-keyed re-issues to pool
replacements, like LU rows.
"""
from __future__ import annotations

import hashlib
import struct
import time
from dataclasses import replace as _dc_replace

import numpy as np

from repro.api.client import SPDCClient
from repro.api.messages import TriSolveTask
from repro.api.transport import resolve_transport
from repro.core.keygen import keygen
from repro.core.protocol import OpRecord, SPDCReport
from repro.distrib.recovery import recover_solve, trisolve_subseed

__all__ = ["LinalgSession", "LinalgVerificationError", "blind_rhs",
           "outsource_solve"]


class LinalgVerificationError(RuntimeError):
    """A triangular-solve round failed verification and could not heal."""


def _lane_rng(digest: bytes, tag: bytes, *idx: int) -> np.random.Generator:
    """Secret-keyed rng on a domain-separated lane of the session digest.

    Unlike `trisolve_subseed` (which ships to servers as a channel tag),
    these lanes NEVER cross the boundary — they key the one-time-pad
    masks and the Freivalds probes, so a server holding every wire byte
    still cannot precompute against either.
    """
    h = hashlib.sha256()
    h.update(digest)
    h.update(tag)
    h.update(struct.pack(f">{len(idx)}q", *idx))
    return np.random.default_rng(int.from_bytes(h.digest()[:8], "big"))


def blind_rhs(rhs_aug, x_aug, digest: bytes, rnd: int, transpose: int):
    """One-time-pad a secret RHS before it crosses the trust boundary.

    Returns (shipped, c): shipped = rhs + A·C where A is the matrix the
    round solves through (X' or X'ᵀ) and C is drawn from the secret mask
    lane at the round's scale — the server's reply is then Y = A⁻¹rhs + C
    and the client unmasks by subtracting C.  The residual check runs on
    the MASKED pair (A·Y vs shipped), so verification needs no unmasking.
    """
    rng = _lane_rng(digest, b"trisolve-mask", rnd)
    scale = float(np.linalg.norm(rhs_aug) / np.sqrt(rhs_aug.size) + 1.0)
    c = rng.standard_normal(rhs_aug.shape).astype(rhs_aug.dtype) * scale
    a = x_aug.T if transpose else x_aug
    return rhs_aug + a @ c, c


class LinalgSession:
    """One matrix, one verified outsourced LU, a growing op plan.

    Every public op (`slogdet`, `solve`, `inv`) shares the factors of the
    session's single factorization — `factorizations` stays 1 however
    many ops run, which is the whole point (and asserted in tests).
    """

    def __init__(
        self,
        m,
        num_servers: int = 2,
        *,
        transport=None,
        faults=None,
        recover: bool = True,
        standby: int = 0,
        method: str = "q2",
        mode: str = "ewd",
        lambda1: int = 128,
        lambda2: int = 128,
        dtype=None,
        growth_safe: bool | None = None,
        solve_rtol: float | None = None,
    ):
        m = np.asarray(m)
        if m.ndim != 2 or m.shape[0] != m.shape[1]:
            raise ValueError(
                f"LinalgSession needs one square matrix, got {m.shape}"
            )
        if dtype is None:
            dtype = m.dtype if np.issubdtype(m.dtype, np.floating) \
                else "float64"
        if growth_safe is None:
            # None = "the op plan's default", which is ON. The det path
            # can afford the rotation cipher's elimination growth (log-
            # magnitude arithmetic), but triangular solves through the
            # factors cannot: rot90 of an SPD kernel matrix is about the
            # worst no-pivot LU input there is (growth ~1e18 on a
            # cond-500 RBF covariance at n=64), while the growth_safe
            # transpose composition keeps near-SPD inputs at growth ~1.
            growth_safe = True
        # equilibrate stays OFF: the op plan stores only the scalar
        # log2_scale the det path consumes — solve/inv recovery would
        # need the full scaling vectors (growth_safe covers f32 instead)
        self.client = SPDCClient(
            lambda1=lambda1, lambda2=lambda2, mode=mode, method=method,
            recover=recover, standby=standby, dtype=dtype,
            growth_safe=growth_safe, equilibrate=False,
        )
        self.transport = resolve_transport(transport)
        self._session = self.client.open_session(
            np.asarray(m, dtype=np.dtype(self.client.dtype.name)),
            num_servers, faults=faults,
        )
        self._session.keep_factors = True
        self.n = int(m.shape[0])
        self.num_servers = int(num_servers)
        self.digest = self._session.digest
        self.solve_rtol = solve_rtol
        self.factorizations = 0
        self._det_result = None
        self._factors = None
        self._x_aug = None
        self._inv_cache = None
        self._ops: list[OpRecord] = []
        self._rounds = 0
        self._meta = self._session.metas[0]
        key = keygen(lambda2, self._session.seeds[0], self.n)
        self._v = np.asarray(key.v, dtype=np.dtype(self.client.dtype.name))

    @property
    def padding(self) -> int:
        """Identity-extension rows the augmented system carries beyond n
        (protocol-exact — DESIGN.md §3)."""
        return int(np.asarray(self._session.x_aug).shape[-1]) - self.n

    # -- the one factorization ----------------------------------------------

    def _ensure_factors(self) -> None:
        if self._factors is not None:
            return
        t0 = time.perf_counter()
        res = self._session.run(self.transport)
        self.factorizations += 1
        self._det_result = res
        if not res.verified:
            raise LinalgVerificationError(
                "factorization rejected by Authenticate (residual "
                f"{float(res.residual):.3e}) and recovery "
                f"{'is disabled' if not self.client.recover else 'failed'}"
                " — the op plan cannot build on unverified factors"
            )
        self._factors = self._session._factors
        self._x_aug = np.asarray(self._session.x_aug)
        # Q2 + Q3 on the accepted factors: the client method (default q2,
        # secret-probed) is sensitive to the FULL product — which the op
        # plan's trisolve rounds build on — while the paper's diagonal-only
        # q3 certifies exactly the band Decipher reads.  A det-only
        # session may accept q3 alone; an op plan may not: in-band relay
        # poisoning can leave downstream strips wrong OFF the diagonal,
        # and q2 is what drives recovery to heal them (tests/test_linalg).
        from repro.core.verify import authenticate, epsilon, growth_estimate

        import jax.numpy as jnp

        l, u = self._factors
        xa = jnp.asarray(self._x_aug)
        # Uncapped growth widening: authenticate's default q3 eps clamps
        # the growth term at q3_growth_cap(n) because a server could
        # plant cancelling strictly-upper entries to dial its own
        # tolerance when q3 is the ONLY check. Here q3 runs strictly
        # after the secret-probed Q2 accepted these same factors, so the
        # widening is not attacker-steerable — and honest no-pivot LU of
        # smooth kernel matrices (the GP workload) routinely shows
        # growth far beyond c·n.
        eps3 = epsilon(
            self._session.partitions, xa.shape[-1], xa, dtype=xa.dtype
        ) * growth_estimate(jnp.asarray(u), xa)
        v3 = authenticate(
            jnp.asarray(l), jnp.asarray(u), xa,
            num_servers=self._session.partitions, method="q3", eps=eps3,
        )
        if not v3.all_ok:
            raise LinalgVerificationError(
                "factors passed the probed check but failed the diagonal "
                f"Q3 check (residual {float(v3.residual):.3e} > eps "
                f"{float(v3.eps):.3e})"
            )
        self._ops.append(OpRecord(
            op="factor", verified=res.verified and v3.all_ok,
            residual=max(float(res.residual), float(v3.residual)),
            wall_s=time.perf_counter() - t0, round_trips=1,
        ))

    # -- public ops ----------------------------------------------------------

    def slogdet(self) -> tuple[float, float]:
        """(sign, log|det|) — free once the factors are verified."""
        t0 = time.perf_counter()
        self._ensure_factors()
        d = self._det_result.det
        self._ops.append(OpRecord(
            op="slogdet", verified=self._det_result.verified,
            residual=float(self._det_result.residual),
            wall_s=time.perf_counter() - t0,
        ))
        return float(d.sign), float(d.logabs)

    def solve(self, b, *, transpose: bool = False) -> np.ndarray:
        """M x = b (or Mᵀ x = b) through the shared verified factors.

        b: (n,) or (n, c).  Secret — it rides the `blind_rhs` chokepoint.
        """
        npdt = np.dtype(self.client.dtype.name)
        b = np.asarray(b, dtype=npdt)
        vec = b.ndim == 1
        b2 = b[:, None] if vec else b
        if b2.ndim != 2 or b2.shape[0] != self.n:
            raise ValueError(
                f"rhs shape {b.shape} does not match matrix size {self.n}"
            )
        v = self._v[:, None]
        ewd = self._meta.mode == "ewd"
        if transpose:
            # M⁻ᵀw = (B⁻ᵀw)/v  (EWD; ·v for EWM) — scale AFTER the round
            y = self._apply_binv(b2, adjoint=True, masked=True, op="solve_t")
            y = y / v if ewd else y * v
        else:
            # M⁻¹w = B⁻¹(w/v) — scaling a MASKED round's input is safe,
            # the pad hides it; inverse rounds must not do this (public
            # RHS would turn into key material on the wire)
            w = b2 / v if ewd else b2 * v
            y = self._apply_binv(w, adjoint=False, masked=True, op="solve")
        return y[:, 0] if vec else y

    def inv(self, *, transpose: bool = False) -> np.ndarray:
        """inv(M) via one wide public-RHS round (cached).

        The round ships only permutation columns; the secret 1/v column
        scaling happens here, client-side, after verification.
        """
        if self._inv_cache is None:
            npdt = np.dtype(self.client.dtype.name)
            eye = np.eye(self.n, dtype=npdt)
            binv = self._apply_binv(eye, adjoint=False, masked=False,
                                    op="inv")
            self._inv_cache = binv / self._v[None, :] \
                if self._meta.mode == "ewd" else binv * self._v[None, :]
        return self._inv_cache.T if transpose else self._inv_cache

    @property
    def report(self) -> SPDCReport:
        """SPDCReport over the WHOLE op plan (ops= one record per op)."""
        base = self._det_result.report if self._det_result is not None \
            else SPDCReport()
        return _dc_replace(base, ops=tuple(self._ops))

    # -- the triangular-solve rounds -----------------------------------------

    def _binv_plan(self, adjoint: bool) -> tuple[int, bool, bool]:
        """(transpose_round, pre_J, post_J) realizing B⁻¹ (or B⁻ᵀ) as one
        G/Gᵀ round with row reversals — the case table in the module
        docstring."""
        k = self._meta.rotate_k % 4
        if self._meta.flipped and k % 2 == 1:  # X = Bᵀ exactly
            return (0, False, False) if adjoint else (1, False, False)
        if not adjoint:
            return {0: (0, False, False), 1: (1, True, False),
                    2: (0, True, True), 3: (1, False, True)}[k]
        return {0: (1, False, False), 1: (0, False, True),
                2: (1, True, True), 3: (0, True, False)}[k]

    def _apply_binv(self, w, *, adjoint, masked, op) -> np.ndarray:
        """B⁻¹w (or B⁻ᵀw) for an (n, c) block, via one verified round."""
        self._ensure_factors()
        t0 = time.perf_counter()
        trans, pre, post = self._binv_plan(adjoint)
        z = w[::-1, :] if pre else w
        n_aug = self._x_aug.shape[0]
        rhs = np.zeros((n_aug, z.shape[1]), dtype=self._x_aug.dtype)
        rhs[: self.n] = z  # border rows zero: inv(X')[:n,:n] = X⁻¹ exactly
        y = self._trisolve_round(rhs, transpose=trans, masked=masked,
                                 op=op, t0=t0)[: self.n]
        return y[::-1, :] if post else y

    def _chunk_tasks(self, shipped, transpose, rnd) -> list[TriSolveTask]:
        l, u = self._factors
        cols = shipped.shape[1]
        splits = np.array_split(np.arange(cols),
                                max(1, min(self.num_servers, cols)))
        tasks = []
        for i, idx in enumerate(splits):
            if idx.size == 0:
                continue
            tasks.append(TriSolveTask(
                server=i, num_servers=self.num_servers,
                l=l, u=u, rhs=shipped[:, idx[0] : idx[-1] + 1],
                subseed=trisolve_subseed(self.digest, rnd, i, 0),
                transpose=int(transpose), col0=int(idx[0]),
                session_id=self._session.session_id,
            ))
        return tasks

    def _tolerance(self) -> float:
        if self.solve_rtol is not None:
            return self.solve_rtol
        eps = float(np.finfo(self._x_aug.dtype).eps)
        # widen by the observed element growth of the no-pivot factors,
        # exactly as verify.epsilon does for the LU checks: a triangular
        # solve through a U with growth ρ loses ~ρ·u·n digits even when
        # everyone is honest. Safe to trust here — unlike Q3's ε-widening
        # (q3_growth_cap), these factors already passed the secret-probed
        # Q2 check, so their growth is the growth of an ACCEPTED
        # factorization, not an attacker-supplied dial.
        from repro.core.verify import growth_estimate

        rho = float(growth_estimate(np.triu(self._factors[1]), self._x_aug))
        return eps * self._x_aug.shape[0] * 256.0 * rho

    def _check_chunk(self, task, res, rnd: int, chunk: int,
                     freivalds: bool) -> float | None:
        """Relative residual if the chunk verifies, None if it fails.

        The echo binding (subseed / col0 / transpose) runs first: a stale
        or replayed chunk from another dispatch fails before any math.
        """
        if res is None or res.subseed != task.subseed \
                or res.col0 != task.col0 or res.transpose != task.transpose:
            return None
        y = np.asarray(res.y)
        if y.shape != task.rhs.shape:
            return None
        a = self._x_aug.T if task.transpose else self._x_aug
        w = task.rhs
        tiny = float(np.finfo(self._x_aug.dtype).tiny)
        if freivalds:
            # secret probe, fresh per (round, chunk, attempt): O(n'²)
            # for a wide chunk instead of O(n'²c), and useless to
            # precompute against — the lane never crosses the boundary
            rng = _lane_rng(self.digest, b"trisolve-probe",
                            rnd, chunk, task.attempt)
            r = rng.standard_normal(a.shape[0]).astype(a.dtype)
            ar = a.T @ r
            num = float(np.linalg.norm(ar @ y - r @ w))
            # backward-error scale of the dot products being compared:
            # ‖aᵀr‖·‖y‖, not ‖r‖·‖w‖ — in the wide inverse round w is a
            # unit-norm permutation block while y carries ‖M⁻¹‖-scale
            # entries, so normalizing by ‖w‖ divides honest rounding
            # noise by a vanishing scale and rejects clean fleets
            den = float(np.linalg.norm(ar) * np.linalg.norm(y)
                        + np.linalg.norm(r @ w)) + tiny
        else:
            num = float(np.linalg.norm(a @ y - w))
            den = float(np.linalg.norm(w)) + tiny
        rel = num / den
        return rel if rel <= self._tolerance() else None

    def _trisolve_round(self, rhs_aug, *, transpose, masked, op, t0):
        """Dispatch one round of column chunks, verify each, heal the
        bad ones, reassemble, unmask."""
        rnd = self._rounds
        self._rounds += 1
        if masked:
            shipped, c = blind_rhs(rhs_aug, self._x_aug, self.digest, rnd,
                                   transpose)
        else:
            shipped, c = rhs_aug, None
        # narrow secret rounds get the full residual; wide public rounds
        # (inverse) get the cheaper Freivalds probe
        freivalds = not masked
        tasks = self._chunk_tasks(shipped, transpose, rnd)
        results = list(self.transport.solve_shards(
            tasks, faults=self._session.plan
        ))
        residuals, bad = [], []
        for i, (t, r) in enumerate(zip(tasks, results)):
            rel = self._check_chunk(t, r, rnd, i, freivalds)
            if rel is None:
                bad.append(i)
            else:
                residuals.append(rel)
        healed = 0
        if bad:
            if not self.client.recover:
                raise LinalgVerificationError(
                    f"trisolve round {rnd} ({op}): chunks {bad} failed "
                    "verification and recover=False"
                )
            reissued: dict[int, TriSolveTask] = {}

            def make_task(i, attempt, phys):
                t = _dc_replace(
                    tasks[i], server=phys, attempt=attempt,
                    subseed=trisolve_subseed(self.digest, rnd, i, attempt),
                )
                reissued[i] = t
                return t

            def verify_chunk(i, res):
                return self._check_chunk(reissued[i], res, rnd, i,
                                         freivalds)

            results, rep = recover_solve(
                results, bad, make_task=make_task,
                verify_chunk=verify_chunk, transport=self.transport,
                num_servers=self.num_servers, standby=self.client.standby,
            )
            if not rep.ok:
                raise LinalgVerificationError(
                    f"trisolve round {rnd} ({op}): recovery exhausted "
                    f"after {rep.rounds} rounds"
                )
            healed = len(rep.events)
            residuals.extend(e.residual for e in rep.events)
        y = np.empty_like(shipped)
        for t, r in zip(tasks, results):
            y[:, t.col0 : t.col0 + t.cols] = np.asarray(r.y)
        if masked:
            y = y - c
        self._ops.append(OpRecord(
            op=op, verified=True,
            residual=max(residuals) if residuals else 0.0,
            wall_s=time.perf_counter() - t0, round_trips=1, healed=healed,
        ))
        return y


def outsource_solve(m, rhs, num_servers: int = 2, *, transpose: bool = False,
                    **session_kwargs):
    """One-shot audited solve facade: factor, verify (Q2+Q3), solve.

    Returns (solution, session). The same standing as
    `core.protocol.outsource_determinant` — the whole PMOP→dispatch→
    blinded-round→verify dance happens inside, so callers (the gateway's
    per-request flush path, scripts) never touch factors or masks.  Hold
    a `LinalgSession` directly instead when several ops should amortize
    one factorization.
    """
    s = LinalgSession(m, num_servers, **session_kwargs)
    y = s.solve(rhs, transpose=transpose)
    return y, s
