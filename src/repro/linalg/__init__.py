"""repro.linalg — differentiable secure linear algebra on one shared LU.

The client-facing secure-linalg family (DESIGN.md §12): `secure_slogdet`,
`secure_solve`, `secure_inv` are differentiable jax ops whose values AND
gradients route through one verified outsourced factorization per matrix
(`LinalgSession`), dispatched over any `repro.api` transport.  The GP
log-likelihood example (examples/gp_loglik.py) is the intended workload
shape: log|Σ| + solves against Σ inside a jitted, grad-ed objective.
"""
from .ops import (
    SecureLinalg,
    default_linalg,
    secure_inv,
    secure_slogdet,
    secure_solve,
)
from .session import (
    LinalgSession,
    LinalgVerificationError,
    blind_rhs,
    outsource_solve,
)

__all__ = [
    "SecureLinalg", "default_linalg",
    "secure_slogdet", "secure_solve", "secure_inv",
    "LinalgSession", "LinalgVerificationError", "blind_rhs",
    "outsource_solve",
]
