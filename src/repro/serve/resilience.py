"""Gateway resilience primitives: admission control, circuit breakers,
and the idempotency-keyed result cache (DESIGN.md §10).

All three are pure bookkeeping on an injected clock — no jax, no threads,
no wall time — so every policy decision is reproducible on the virtual
clock the gateway tests already drive. The gateway (serve.spdc_gateway)
owns the instances and calls them under its lock.

Admission vs backpressure (DESIGN.md §10.1): ``GatewayOverloaded``
(serve.queue) is the *capacity* door — the gateway-wide pending total hit
its bound, nobody gets in regardless of who they are.
``AdmissionRejected`` is the *policy* door — THIS tenant exceeded its
token-bucket rate or its pending quota, while other tenants keep being
served. The two are distinct types because clients must react
differently: backpressure means retry against another gateway; an
admission reject means slow down (the gateway is healthy).

Circuit breaker (DESIGN.md §10.2): per-BUCKET, not per-gateway — the
failure domain of a poisoned size/config mix is exactly its compiled
sweep, so that is the unit that trips. Unverified-rate counts as failure
alongside sweep exceptions: a bucket whose results keep failing
verification is burning device time to produce answers nobody can accept,
which is operationally identical to crashing.
"""

from __future__ import annotations

import zlib
from collections import OrderedDict
from dataclasses import dataclass, field

__all__ = [
    "AdmissionRejected",
    "BreakerOpen",
    "TokenBucket",
    "AdmissionController",
    "CircuitBreaker",
    "ResultCache",
]


class AdmissionRejected(RuntimeError):
    """Per-tenant policy rejection: rate limit or pending quota.

    Raised at submit time, before anything is enqueued; ``reason`` is
    "rate" (token bucket empty) or "quota" (tenant's pending cap hit).
    Distinct from GatewayOverloaded — the gateway has capacity, this
    tenant is over ITS share.
    """

    def __init__(self, msg: str, *, tenant: str, reason: str):
        super().__init__(msg)
        self.tenant = tenant
        self.reason = reason


class BreakerOpen(RuntimeError):
    """Fast-fail rejection: the request's bucket has its breaker open.

    ``retry_after_s`` is the time until the next half-open probe — the
    client's backoff hint. Nothing is enqueued.
    """

    def __init__(self, msg: str, *, bucket: str, retry_after_s: float):
        super().__init__(msg)
        self.bucket = bucket
        self.retry_after_s = retry_after_s


# ------------------------------------------------------------- admission


class TokenBucket:
    """Classic token bucket on an injected clock: ``rate`` tokens/sec
    refill, at most ``burst`` banked. Deterministic — refill is computed
    from the now() values the caller passes, never wall time."""

    __slots__ = ("rate", "burst", "tokens", "_last")

    def __init__(self, rate: float, burst: float, *, now: float = 0.0):
        if rate <= 0 or burst <= 0:
            raise ValueError("token bucket needs rate > 0 and burst > 0")
        self.rate = float(rate)
        self.burst = float(burst)
        self.tokens = float(burst)  # start full: a fresh tenant may burst
        self._last = float(now)

    def try_take(self, now: float, amount: float = 1.0) -> bool:
        if now > self._last:
            self.tokens = min(self.burst, self.tokens + (now - self._last) * self.rate)
            self._last = now
        if self.tokens >= amount:
            self.tokens -= amount
            return True
        return False


class AdmissionController:
    """Per-tenant rate limiting + pending quotas (DESIGN.md §10.1).

    Tenancy rides *accounting*, not the BucketKey: requests from every
    tenant still coalesce into the same shared sweeps (a tenant dimension
    on the key would shatter batching — the whole point of the gateway).
    What is per-tenant is the right to enter the queue.

    Lifecycle per admitted request: ``charge`` (token) → ``acquire_slot``
    (quota, on enqueue) → ... → ``release_slot`` (on delivery, success or
    failure). Cache hits charge a token but never hold a slot — they cost
    O(hash), not sweep capacity.
    """

    def __init__(self, config=None):
        # config: configs.spdc.AdmissionConfig | None (None = everything off)
        self.config = config
        self._buckets: dict[str, TokenBucket] = {}
        self._pending: dict[str, int] = {}

    @property
    def enabled(self) -> bool:
        c = self.config
        return c is not None and (
            c.rate_per_sec is not None or c.max_pending_per_tenant is not None
        )

    def charge(self, tenant: str, now: float) -> None:
        """Consume one rate token; raises AdmissionRejected("rate")."""
        c = self.config
        if c is None or c.rate_per_sec is None:
            return
        tb = self._buckets.get(tenant)
        if tb is None:
            burst = c.burst if c.burst is not None else max(1.0, c.rate_per_sec)
            tb = self._buckets[tenant] = TokenBucket(
                c.rate_per_sec, burst, now=now
            )
        if not tb.try_take(now):
            raise AdmissionRejected(
                f"tenant {tenant!r} over rate limit "
                f"({c.rate_per_sec}/s, burst {tb.burst:g}); slow down",
                tenant=tenant, reason="rate",
            )

    def acquire_slot(self, tenant: str) -> None:
        """Claim one pending slot; raises AdmissionRejected("quota")."""
        c = self.config
        held = self._pending.get(tenant, 0)
        if (
            c is not None
            and c.max_pending_per_tenant is not None
            and held >= c.max_pending_per_tenant
        ):
            raise AdmissionRejected(
                f"tenant {tenant!r} has {held} requests pending "
                f"(max_pending_per_tenant={c.max_pending_per_tenant})",
                tenant=tenant, reason="quota",
            )
        self._pending[tenant] = held + 1

    def release_slot(self, tenant: str) -> None:
        held = self._pending.get(tenant, 0)
        if held <= 1:
            self._pending.pop(tenant, None)
        else:
            self._pending[tenant] = held - 1

    def pending_of(self, tenant: str) -> int:
        return self._pending.get(tenant, 0)

    @property
    def total_pending(self) -> int:
        return sum(self._pending.values())

    def pending_by_tenant(self) -> dict[str, int]:
        return dict(self._pending)


# --------------------------------------------------------------- breaker


def _jitter_u(seed: int, attempt: int) -> float:
    """Deterministic uniform in [-1, 1) keyed by (breaker, open count) —
    probes are de-synchronized across buckets without wall-clock
    randomness, so virtual-clock tests can predict the exact probe time."""
    h = zlib.crc32(f"{seed}:{attempt}".encode()) & 0xFFFFFFFF
    return (h / 2**31) - 1.0


@dataclass
class CircuitBreaker:
    """closed → open → half-open breaker for one gateway bucket.

    Opens on either signal (DESIGN.md §10.2):
      * ``failure_threshold`` CONSECUTIVE sweep failures (the sweep
        raised — compile error, transport death, pathological config);
      * the EWMA of the bucket's per-flush unverified-rate exceeding
        ``max_unverified_rate`` after ``min_samples`` flushes.

    While open, ``allow()`` answers "open" (the gateway fast-fails or
    detours direct) until the cooldown elapses; then exactly ONE "probe"
    is granted (half-open). The probe request flushes through the normal
    sweep; its outcome closes the breaker (success: full reset) or
    re-opens it with doubled cooldown. Cooldowns are
    base·2^(opens−1) capped at max, ±jitter drawn deterministically from
    the bucket identity — no thundering herd, no flaky tests.
    """

    config: object  # configs.spdc.BreakerConfig
    seed: int = 0
    state: str = "closed"  # "closed" | "open" | "half_open"
    consecutive_failures: int = 0
    opens: int = 0  # lifetime open transitions (drives backoff)
    next_probe_at: float = 0.0
    unverified_ewma: float = 0.0
    samples: int = 0
    #: set while a half-open probe's flush is in flight
    probe_pending: bool = field(default=False, repr=False)

    def _cooldown(self) -> float:
        c = self.config
        base = c.cooldown_base_s * (2.0 ** max(self.opens - 1, 0))
        base = min(base, c.cooldown_max_s)
        return max(base * (1.0 + c.probe_jitter * _jitter_u(self.seed, self.opens)),
                   1e-9)

    def _trip(self, now: float) -> None:
        self.state = "open"
        self.opens += 1
        self.probe_pending = False
        self.next_probe_at = now + self._cooldown()

    def allow(self, now: float) -> str:
        """Admission verdict for one submission: "ok" | "probe" | "open"."""
        if not self.config.enabled or self.state == "closed":
            return "ok"
        if self.state == "open" and now >= self.next_probe_at:
            self.state = "half_open"
            self.probe_pending = True
            return "probe"
        if self.state == "half_open" and not self.probe_pending:
            # previous probe was admitted but its flush hasn't reported
            # yet — shouldn't happen (probe_pending guards it), but a
            # second probe is never granted
            return "open"
        return "open"

    def retry_after(self, now: float) -> float:
        return max(0.0, self.next_probe_at - now)

    def revert_probe(self) -> None:
        """Undo an ``allow() == "probe"`` grant whose request never made
        it into the queue (shed by quota or gateway capacity after the
        verdict). ``next_probe_at`` is left unchanged — it is already in
        the past — so the NEXT submission gets a fresh probe instead of
        the bucket fast-failing forever on a probe that no flush will
        ever ``record()``."""
        if self.state == "half_open" and self.probe_pending:
            self.state = "open"
            self.probe_pending = False

    def record(self, now: float, *, failed: bool, unverified_rate: float = 0.0) -> str:
        """Feed one flush outcome; returns the resulting state.

        ``failed`` — the sweep raised. ``unverified_rate`` — fraction of
        the flush's REAL requests (padding dummies excluded) that failed
        verification; only meaningful when the sweep completed.
        """
        if not self.config.enabled:
            return self.state
        if self.state == "half_open":
            self.probe_pending = False
            if failed or (
                self.config.max_unverified_rate is not None
                and unverified_rate > self.config.max_unverified_rate
            ):
                self._trip(now)  # probe failed: re-open, doubled cooldown
            else:
                self.reset()  # probe verified: full recovery
            return self.state
        if failed:
            self.consecutive_failures += 1
            if self.consecutive_failures >= self.config.failure_threshold:
                self._trip(now)
            return self.state
        self.consecutive_failures = 0
        if self.config.max_unverified_rate is not None:
            a = self.config.unverified_alpha
            self.unverified_ewma = (
                a * unverified_rate + (1.0 - a) * self.unverified_ewma
            )
            self.samples += 1
            if (
                self.samples >= self.config.min_samples
                and self.unverified_ewma > self.config.max_unverified_rate
            ):
                self._trip(now)
        return self.state

    def reset(self) -> None:
        self.state = "closed"
        self.consecutive_failures = 0
        self.unverified_ewma = 0.0
        self.samples = 0
        self.probe_pending = False
        # `opens` is NOT reset: a bucket that keeps flapping keeps paying
        # longer cooldowns, which is the point of the backoff


# ----------------------------------------------------------------- cache


class ResultCache:
    """Bounded LRU for verified determinant results (cache-aside,
    DESIGN.md §10.3).

    Keys are (BucketKey, tenant, content-digest) tuples built by the
    gateway: the digest covers the exact matrix bytes + shape + dtype,
    and the BucketKey carries the complete security tuple — so a hit can
    never cross security configs, compute dtypes, transports, or tenants.
    Only VERIFIED results are stored; failures and rejected verdicts are
    never cached (a poisoned answer must not outlive its sweep).
    """

    def __init__(self, max_entries: int):
        if max_entries < 1:
            raise ValueError("cache max_entries must be >= 1")
        self.max_entries = int(max_entries)
        self._data: "OrderedDict[object, object]" = OrderedDict()
        self.evictions = 0

    def __len__(self) -> int:
        return len(self._data)

    def get(self, key):
        val = self._data.get(key)
        if val is not None:
            self._data.move_to_end(key)
        return val

    def put(self, key, value) -> None:
        self._data[key] = value
        self._data.move_to_end(key)
        while len(self._data) > self.max_entries:
            self._data.popitem(last=False)
            self.evictions += 1

    def clear(self) -> None:
        self._data.clear()
