"""Gateway observability: counters, streaming quantiles, snapshots, and
the /healthz + /metrics text surfaces (DESIGN.md §10).

The gateway (serve.spdc_gateway) records every event — submission,
admission rejection, flush, verdict, cache hit — into ONE
``GatewayMetrics`` registry, and the same event objects are handed to the
operator hook points (``on_flush`` / ``on_verdict`` / ``on_reject``), so
benchmarks, tests, and dashboards all consume identical numbers: there is
no separate "test instrumentation" path that could drift from what a
deployment sees.

Quantiles (queue wait, sweep latency, flush size) come from a
deterministic bounded-memory streaming sketch: a sorted weighted-bin
histogram that, when full, merges the two adjacent bins closest in value
(the Ben-Haim/Tom-Toledano streaming-histogram step). No randomness — the
same event stream always yields the
same percentile estimates, so virtual-clock tests can assert on them —
and memory is O(capacity) no matter how long the gateway lives. min/max
are tracked exactly, and estimates degrade gracefully (each compression
at most halves the local resolution of the CDF).

Snapshots are schema-versioned (``MetricsSnapshot.SCHEMA_VERSION``): the
key set of ``as_dict()`` is a compatibility contract guarded by
tests/test_resilience.py, so dashboards built on /metrics don't silently
break when the gateway grows new counters (additions bump the version).
"""

from __future__ import annotations

from dataclasses import dataclass, field

__all__ = [
    "QuantileSketch",
    "FlushEvent",
    "VerdictEvent",
    "RejectEvent",
    "GatewayMetrics",
    "MetricsSnapshot",
    "render_prometheus",
    "render_healthz",
]


class QuantileSketch:
    """Deterministic bounded-memory streaming quantile estimator.

    Holds at most ``capacity`` sorted (value, weight) bins. New
    observations enter as weight-1 bins; when the histogram overflows, the
    two ADJACENT bins closest in value merge into their weighted midpoint
    (the Ben-Haim/Tom-Toledano streaming-histogram step). Merging by
    value gap — not by position — keeps bins spread across the observed
    range, so a drifting stream doesn't collapse its mass into a few
    stale mega-bins; mass is preserved exactly (== observation count).
    ``quantile(q)`` answers from the weighted bins; min/max are exact.
    All operations are deterministic — identical streams give identical
    answers, which is what lets the overload tier assert sharp p99 bounds
    on a virtual clock.
    """

    __slots__ = ("capacity", "_items", "count", "total", "min", "max")

    def __init__(self, capacity: int = 512):
        if capacity < 8:
            raise ValueError("sketch capacity must be >= 8")
        self.capacity = int(capacity)
        self._items: list[tuple[float, int]] = []  # (value, weight)
        self.count = 0  # observations seen (not samples kept)
        self.total = 0.0
        self.min: float | None = None
        self.max: float | None = None

    def observe(self, value: float) -> None:
        import bisect

        value = float(value)
        self.count += 1
        self.total += value
        self.min = value if self.min is None else min(self.min, value)
        self.max = value if self.max is None else max(self.max, value)
        bisect.insort(self._items, (value, 1))
        if len(self._items) > self.capacity:
            self._compress()

    def _compress(self) -> None:
        it = self._items
        # merge the adjacent bin pair closest in value (first such pair on
        # ties) into its weighted midpoint: mass is preserved exactly, and
        # gap-directed merging keeps bins spread over the observed range
        # instead of snowballing old mass into a few stale mega-bins
        gi = min(range(len(it) - 1), key=lambda i: it[i + 1][0] - it[i][0])
        (v1, w1), (v2, w2) = it[gi], it[gi + 1]
        w = w1 + w2
        it[gi:gi + 2] = [((v1 * w1 + v2 * w2) / w, w)]

    def quantile(self, q: float) -> float | None:
        """Weighted percentile estimate; None while empty. q in [0, 1]."""
        if not self._items:
            return None
        if q <= 0.0:
            return self.min
        if q >= 1.0:
            return self.max
        mass = sum(w for _, w in self._items)
        target = q * mass
        acc = 0.0
        for v, w in self._items:
            acc += w
            if acc >= target:
                return v
        return self._items[-1][0]

    @property
    def mean(self) -> float | None:
        return self.total / self.count if self.count else None

    def summary(self) -> dict:
        """p50/p90/p99 + exact extremes, ready for a snapshot row."""
        return {
            "count": self.count,
            "mean": self.mean,
            "min": self.min,
            "max": self.max,
            "p50": self.quantile(0.50),
            "p90": self.quantile(0.90),
            "p99": self.quantile(0.99),
        }


# ---------------------------------------------------------------- events


@dataclass(frozen=True)
class FlushEvent:
    """One bucket sweep, successful or not (``error`` set when it raised)."""

    bucket: str  # BucketKey label
    reason: str  # "full" | "timeout" | "drain"
    batch: int  # real requests in the sweep
    padded_batch: int  # batch after pad_batches dummies
    queue_waits_s: tuple[float, ...]  # per-request submit→flush wait
    sweep_s: float  # device sweep wall time (virtual-clock delta in tests)
    recovered: bool = False
    error: str | None = None


@dataclass(frozen=True)
class VerdictEvent:
    """One client request's outcome, as delivered."""

    rid: int
    bucket: str | None  # None for direct / oversize requests
    tenant: str
    verified: bool
    latency_s: float
    flush_reason: str  # "full"|"timeout"|"drain"|"direct"|"cache"|"coalesced"
    cache_hit: bool = False
    error: str | None = None


@dataclass(frozen=True)
class RejectEvent:
    """A typed admission refusal — nothing was enqueued."""

    reason: str  # "overload" | "rate" | "quota" | "breaker"
    tenant: str
    bucket: str | None = None


# ------------------------------------------------------------- registry


@dataclass
class _BucketMetrics:
    flushes: int = 0
    requests: int = 0
    verified: int = 0
    unverified: int = 0
    failed: int = 0
    recovered_flushes: int = 0
    sweep_errors: int = 0
    flush_size: QuantileSketch = field(default_factory=lambda: QuantileSketch(128))
    queue_wait_s: QuantileSketch = field(default_factory=QuantileSketch)
    sweep_s: QuantileSketch = field(default_factory=QuantileSketch)


@dataclass
class _TenantMetrics:
    submitted: int = 0
    served: int = 0
    rejected_rate: int = 0
    rejected_quota: int = 0
    rejected_overload: int = 0
    rejected_breaker: int = 0


class GatewayMetrics:
    """Passive registry the gateway records events into (under its lock).

    Pure bookkeeping — no clock, no locks of its own, no jax. Live gauges
    (queue depth, breaker states, cache entries, tenant pending) belong to
    the gateway's own structures and are folded in at snapshot() time via
    the ``gauges`` argument, so the registry never holds a second copy of
    serving state that could drift.
    """

    def __init__(self):
        self.counters: dict[str, int] = {
            "submitted": 0,
            "admitted": 0,
            "served": 0,
            "failed": 0,
            "direct": 0,
            "rejected_overload": 0,
            "rejected_rate": 0,
            "rejected_quota": 0,
            "rejected_breaker": 0,
            "cache_hits": 0,
            "cache_misses": 0,
            "coalesced": 0,
            "breaker_opens": 0,
            "breaker_probes": 0,
            "breaker_closes": 0,
        }
        self.request_latency_s = QuantileSketch()
        self._buckets: dict[str, _BucketMetrics] = {}
        self._tenants: dict[str, _TenantMetrics] = {}

    # -- recording (gateway-internal) -----------------------------------

    def bucket(self, label: str) -> _BucketMetrics:
        return self._buckets.setdefault(label, _BucketMetrics())

    def tenant(self, name: str) -> _TenantMetrics:
        return self._tenants.setdefault(name, _TenantMetrics())

    def record_submit(self, tenant: str) -> None:
        self.counters["submitted"] += 1
        self.tenant(tenant).submitted += 1

    def record_reject(self, ev: RejectEvent) -> None:
        key = f"rejected_{ev.reason}"
        self.counters[key] = self.counters.get(key, 0) + 1
        t = self.tenant(ev.tenant)
        setattr(t, key, getattr(t, key) + 1)

    def record_flush(self, ev: FlushEvent) -> None:
        b = self.bucket(ev.bucket)
        b.flushes += 1
        b.requests += ev.batch
        b.flush_size.observe(ev.batch)
        for w in ev.queue_waits_s:
            b.queue_wait_s.observe(w)
        b.sweep_s.observe(ev.sweep_s)
        if ev.recovered:
            b.recovered_flushes += 1
        if ev.error is not None:
            b.sweep_errors += 1

    def record_verdict(self, ev: VerdictEvent) -> None:
        self.request_latency_s.observe(ev.latency_s)
        if ev.error is not None:
            self.counters["failed"] += 1
        else:
            # tenant served mirrors the global served/failed split — a
            # failed request is not "served" in either view
            self.tenant(ev.tenant).served += 1
            self.counters["served"] += 1
        if ev.bucket is not None:
            b = self.bucket(ev.bucket)
            if ev.error is not None:
                b.failed += 1
            elif ev.verified:
                b.verified += 1
            else:
                b.unverified += 1

    # -- snapshotting ----------------------------------------------------

    def snapshot(self, gauges: dict | None = None) -> "MetricsSnapshot":
        gauges = gauges or {}
        bucket_gauges = gauges.get("buckets", {})
        buckets = {}
        for label, b in sorted(self._buckets.items()):
            extra = bucket_gauges.get(label, {})
            buckets[label] = {
                "depth": extra.get("depth", 0),
                "breaker": extra.get("breaker", "closed"),
                "flushes": b.flushes,
                "requests": b.requests,
                "verified": b.verified,
                "unverified": b.unverified,
                "failed": b.failed,
                "recovered_flushes": b.recovered_flushes,
                "sweep_errors": b.sweep_errors,
                "flush_size": b.flush_size.summary(),
                "queue_wait_s": b.queue_wait_s.summary(),
                "sweep_s": b.sweep_s.summary(),
            }
        # buckets with live gauges (e.g. an open breaker) that never
        # recorded a flush still must surface — an operator staring at a
        # stuck bucket needs to see its state, not an absence
        for label, extra in sorted(bucket_gauges.items()):
            if label not in buckets:
                empty = _BucketMetrics()
                buckets[label] = {
                    "depth": extra.get("depth", 0),
                    "breaker": extra.get("breaker", "closed"),
                    "flushes": 0, "requests": 0, "verified": 0,
                    "unverified": 0, "failed": 0, "recovered_flushes": 0,
                    "sweep_errors": 0,
                    "flush_size": empty.flush_size.summary(),
                    "queue_wait_s": empty.queue_wait_s.summary(),
                    "sweep_s": empty.sweep_s.summary(),
                }
        tenant_pending = gauges.get("tenant_pending", {})
        tenants = {
            name: {
                "pending": tenant_pending.get(name, 0),
                "submitted": t.submitted,
                "served": t.served,
                "rejected_rate": t.rejected_rate,
                "rejected_quota": t.rejected_quota,
                "rejected_overload": t.rejected_overload,
                "rejected_breaker": t.rejected_breaker,
            }
            for name, t in sorted(self._tenants.items())
        }
        hits = self.counters["cache_hits"]
        misses = self.counters["cache_misses"]
        lookups = hits + misses
        return MetricsSnapshot(
            counters=dict(self.counters),
            pending=gauges.get("pending", 0),
            request_latency_s=self.request_latency_s.summary(),
            buckets=buckets,
            tenants=tenants,
            cache={
                "entries": gauges.get("cache_entries", 0),
                "hits": hits,
                "misses": misses,
                "coalesced": self.counters["coalesced"],
                "hit_rate": (hits / lookups) if lookups else None,
                "evictions": gauges.get("cache_evictions", 0),
            },
        )


@dataclass(frozen=True)
class MetricsSnapshot:
    """Point-in-time operational view — the unit dashboards consume.

    ``as_dict()``'s key schema is versioned: tests pin the exact key set
    for SCHEMA_VERSION, so any widening is a deliberate, visible bump.
    """

    SCHEMA_VERSION = 1

    counters: dict
    pending: int
    request_latency_s: dict
    buckets: dict
    tenants: dict
    cache: dict

    def as_dict(self) -> dict:
        return {
            "schema_version": self.SCHEMA_VERSION,
            "counters": dict(self.counters),
            "pending": self.pending,
            "request_latency_s": dict(self.request_latency_s),
            "buckets": {k: dict(v) for k, v in self.buckets.items()},
            "tenants": {k: dict(v) for k, v in self.tenants.items()},
            "cache": dict(self.cache),
        }

    @property
    def open_breakers(self) -> list[str]:
        return [
            label for label, b in self.buckets.items()
            if b.get("breaker") not in (None, "closed")
        ]


# ------------------------------------------------------------- rendering


def _fmt(v) -> str:
    if v is None:
        return "NaN"
    if isinstance(v, bool):
        return "1" if v else "0"
    if isinstance(v, float):
        return repr(round(v, 9))
    return str(v)


def render_prometheus(snap: MetricsSnapshot) -> str:
    """Prometheus-style text exposition of a snapshot (the /metrics body).

    Stable line grammar: ``spdc_gateway_<name>{label="..."} value``.
    Quantile summaries expand to ``_p50`` / ``_p99`` / ``_max`` series.
    """
    lines = [
        f"# spdc gateway metrics (schema v{snap.SCHEMA_VERSION})",
    ]
    for name, v in sorted(snap.counters.items()):
        lines.append(f"spdc_gateway_{name}_total {_fmt(v)}")
    lines.append(f"spdc_gateway_pending {_fmt(snap.pending)}")
    for q in ("p50", "p99", "max"):
        lines.append(
            f"spdc_gateway_request_latency_seconds_{q} "
            f"{_fmt(snap.request_latency_s.get(q))}"
        )
    for label, b in sorted(snap.buckets.items()):
        tag = f'{{bucket="{label}"}}'
        for k in ("depth", "flushes", "requests", "verified", "unverified",
                  "failed", "recovered_flushes", "sweep_errors"):
            lines.append(f"spdc_gateway_bucket_{k}{tag} {_fmt(b[k])}")
        state = b.get("breaker", "closed")
        for s in ("closed", "open", "half_open"):
            lines.append(
                f'spdc_gateway_breaker_state{{bucket="{label}",state="{s}"}} '
                f"{_fmt(state == s)}"
            )
        for series in ("queue_wait_s", "sweep_s", "flush_size"):
            for q in ("p50", "p99", "max"):
                lines.append(
                    f"spdc_gateway_bucket_{series}_{q}{tag} "
                    f"{_fmt(b[series].get(q))}"
                )
    for name, t in sorted(snap.tenants.items()):
        tag = f'{{tenant="{name}"}}'
        for k, v in sorted(t.items()):
            lines.append(f"spdc_gateway_tenant_{k}{tag} {_fmt(v)}")
    for k, v in sorted(snap.cache.items()):
        lines.append(f"spdc_gateway_cache_{k} {_fmt(v)}")
    return "\n".join(lines) + "\n"


def render_healthz(snap: MetricsSnapshot, *, max_pending: int | None = None) -> dict:
    """Health verdict from a snapshot (the /healthz body).

    ok        — serving normally;
    degraded  — at least one bucket's breaker is not closed (that bucket
                fast-fails or detours direct, everything else serves);
    overloaded— the pending queue is at/over the backpressure limit, new
                submissions are being shed.
    The dict renders as a one-line-per-key text body; ``status`` first.
    """
    status = "ok"
    if snap.open_breakers:
        status = "degraded"
    if max_pending is not None and snap.pending >= max_pending:
        status = "overloaded"
    return {
        "status": status,
        "pending": snap.pending,
        "open_breakers": snap.open_breakers,
        "served": snap.counters.get("served", 0),
        "failed": snap.counters.get("failed", 0),
        "rejected": sum(
            v for k, v in snap.counters.items() if k.startswith("rejected_")
        ),
    }
