"""SPDC edge gateway — async micro-batching determinant service.

This is the layer that turns the protocol reproduction into a *service*
(ROADMAP north star; DESIGN.md §5): many clients each submit one matrix;
the gateway coalesces them into the batched protocol sweeps that PR 1 made
fast and PR 2 made fault-tolerant.

    client ──submit(M)──▶ gateway ──bucket by (n', security config)──▶
      ┌───────────────┐   flush on max_batch / max_wait_us
      │ bucket n'=64  │──▶ ONE outsource_determinant_mixed sweep
      │ bucket n'=256 │──▶   (one cipher+augment per request, one jitted
      └───────────────┘      N-server LU, one batched verify, per-request
                             Decipher) ──▶ per-request GatewayResult

Two surfaces:

  * ``SPDCGateway`` — the synchronous engine. `submit()` enqueues (and by
    default flushes a bucket the instant it fills), `poll(now)` flushes
    buckets whose oldest request exceeded the wait budget, `drain()`
    flushes everything. The clock is injected, so tests drive flush
    policy with virtual time.
  * ``AsyncSPDCGateway`` — the asyncio service: ``await submit(m)``
    resolves to that request's GatewayResult; a background flusher task
    runs the device sweeps off the event loop thread.

Production hardening (DESIGN.md §10) rides the same submit path:

  * per-tenant **admission control** — ``submit(tenant=...)`` charges a
    token bucket and a pending quota; over-budget tenants get a typed
    ``AdmissionRejected`` while the gateway keeps serving everyone else
    (tenancy is accounting-only: all tenants coalesce into shared sweeps);
  * a **circuit breaker per bucket** — consecutive sweep failures or a
    high unverified-rate open the breaker, and new submissions to that
    bucket fast-fail (``BreakerOpen``) or detour to the direct path until
    a half-open probe proves the bucket healthy again;
  * an **idempotency-keyed result cache** — det is deterministic given
    (matrix bytes, security tuple), so repeated matrices answer from a
    bounded LRU in O(hash), and concurrent identical submissions
    single-flight onto one sweep;
  * an **observability surface** — every event lands in a
    ``GatewayMetrics`` registry (``metrics_snapshot()`` /
    ``render_metrics()`` / ``healthz()``) AND fires the structured hook
    points ``on_flush`` / ``on_verdict`` / ``on_reject``, so tests,
    benchmarks, and dashboards read the same numbers.

Faults and recovery are per-bucket: a tampering server poisons only the
sweeps it participates in, and when a bucket's security config says
`recover=True`, the verification-driven re-dispatch (DESIGN.md §4) heals
that bucket's batch alone — co-batched requests in other buckets never
pay for it (test_gateway.py::test_tampered_bucket_isolated).
"""

from __future__ import annotations

import hashlib
import threading
import time
import zlib
from collections import OrderedDict
from dataclasses import dataclass, replace

import numpy as np

from repro.api.transport import Transport, TransportConfig
from repro.configs.spdc import SPDC_GATEWAY_DEFAULT, SPDCGatewayConfig
from repro.core.protocol import outsource_determinant_mixed, resolve_dtype

from .locking import assert_owns_lock
from .metrics import (
    FlushEvent,
    GatewayMetrics,
    RejectEvent,
    VerdictEvent,
    render_healthz,
    render_prometheus,
)
from .queue import (
    BucketKey,
    DetRequest,
    GatewayOverloaded,
    GatewayStats,
    MicroBatchQueue,
    NoBucketFits,
    bucket_size_for,
)
from .resilience import (
    AdmissionController,
    AdmissionRejected,
    BreakerOpen,
    CircuitBreaker,
    ResultCache,
)

__all__ = [
    "GatewayResult",
    "SPDCGateway",
    "AsyncSPDCGateway",
    "GatewayOverloaded",
    "AdmissionRejected",
    "BreakerOpen",
]

#: per-request security-config overrides submit() accepts (the BucketKey
#: fields minus pad_to, which bucketing derives, and minus op, which is
#: submit()'s own first-class keyword)
_OVERRIDE_KEYS = frozenset(
    {"num_servers", "mode", "method", "lambda1", "lambda2", "recover",
     "standby", "straggler_deadline", "dtype", "growth_safe",
     "equilibrate", "transport", "rateless"}
)

#: secure-linalg operations the gateway serves (DESIGN.md §12): the
#: determinant family rides the coalesced batched sweep; "solve" runs one
#: LinalgSession per request on the bucket's warm transport.
_OPS = ("det", "slogdet", "solve")

#: warmup-dummy cache bound: entries are (n_bucket, dtype)-keyed full
#: matrices, so a long-lived gateway serving a diverse size/dtype mix must
#: not accumulate one per distinct bucket forever (the pre-fix cache was
#: keyed by n_bucket alone AND unbounded)
_DUMMY_CACHE_MAX = 8


def _partition_divisor(num_servers: int, rateless: bool) -> int:
    """The strip count a padded size must divide into: N for deadline-based
    sweeps, F = overdecompose·N for rateless ones (the bucket grid has to
    accommodate the over-decomposed partition, not just the fleet size)."""
    if not rateless:
        return num_servers
    from repro.configs.spdc import RATELESS_DEFAULT

    return num_servers * RATELESS_DEFAULT.overdecompose


def allowed_batch_sizes(max_batch: int) -> tuple[int, ...]:
    """The bounded set of sweep batch shapes under pad_batches: powers of
    two up to max_batch, plus max_batch itself."""
    sizes = []
    b = 1
    while b < max_batch:
        sizes.append(b)
        b *= 2
    sizes.append(max_batch)
    return tuple(sizes)


@dataclass
class GatewayResult:
    """One client request's outcome, unpacked from its bucket's sweep.

    `error` is set (with det=None, verified=False) when the request's
    sweep raised instead of completing — co-batched requests each get
    their own failed result rather than disappearing.
    """

    rid: int
    det: object  # core.decipher.Determinant (None when error is set)
    verified: bool
    residual: float
    n: int  # client's raw matrix size
    pad_to: int  # bucket size the sweep ran at (== n for direct calls)
    batch: int  # how many requests shared the sweep
    flush_reason: str  # "full"|"timeout"|"drain"|"direct"|"cache"|"coalesced"
    submitted_at: float
    completed_at: float
    recovery: object | None = None  # bucket's RecoveryReport, if it healed
    error: str | None = None  # sweep failure, delivered per-request
    tenant: str = "default"
    cache_hit: bool = False  # answered from the idempotency cache
    op: str = "det"  # which secure-linalg op served this request
    #: op="slogdet": the Determinant unpacked into its overflow-safe pair
    #: (det still carries the full object; these are the client-facing
    #: answer shape, matching jnp.linalg.slogdet)
    sign: float | None = None
    logabs: float | None = None
    #: op="solve": the (n,) / (n, c) solution array (det is None)
    solution: object = None

    @property
    def latency_s(self) -> float:
        return self.completed_at - self.submitted_at


class _InFlight:
    """Single-flight bookkeeping for one idempotency key: the leader's
    rid plus follower requests registered while the leader is pending."""

    __slots__ = ("leader_rid", "followers")

    def __init__(self, leader_rid: int):
        self.leader_rid = leader_rid
        self.followers: list[DetRequest] = []


class SPDCGateway:
    """Synchronous micro-batching engine (see module docstring).

    config: an SPDCGatewayConfig preset (configs.spdc). Its `spdc` field
        supplies each request's default security config; `submit()`
        keyword overrides open separate buckets. `admission`/`breaker`/
        `cache` configure the resilience layer (DESIGN.md §10).
    clock: monotonic-seconds source; injectable for deterministic tests.
    faults_for: optional hook BucketKey -> FaultPlan | None injecting
        misbehaving servers into chosen buckets' sweeps (benchmarks and
        fault-isolation tests; a real deployment has real faults).
    auto_flush: flush a bucket synchronously inside submit() the moment it
        reaches max_batch. AsyncSPDCGateway disables this so sweeps always
        run on its flusher thread.
    on_flush / on_verdict / on_reject: structured observer hooks, called
        with metrics.FlushEvent / VerdictEvent / RejectEvent AFTER the
        gateway's own bookkeeping (outside its lock). The internal
        GatewayMetrics registry consumes the identical events, so hook
        consumers and the /metrics surface can never disagree. Hooks must
        not raise.
    """

    def __init__(
        self,
        config: SPDCGatewayConfig = SPDC_GATEWAY_DEFAULT,
        *,
        clock=time.monotonic,
        faults_for=None,
        auto_flush: bool = True,
        on_flush=None,
        on_verdict=None,
        on_reject=None,
    ):
        if not config.buckets:
            raise ValueError("gateway config needs at least one bucket size")
        # validate the preset bucket list against the default server count
        # up front, naming the offending bucket: a bucket that fails the
        # schedule's divisibility rule is a config bug, and catching it at
        # construction beats every request of that size silently riding
        # the synthesized-fallback (or, pre-fix, the direct) path
        divisor = _partition_divisor(
            config.spdc.num_servers, config.spdc.rateless
        )
        for b in config.buckets:
            if b % divisor != 0 or b // divisor <= 1:
                raise ValueError(
                    f"bucket {b} in {tuple(config.buckets)} is not "
                    f"servable by num_servers={config.spdc.num_servers}"
                    + (" under rateless over-decomposition"
                       if config.spdc.rateless else "")
                    + f" (need n' % {divisor} == 0 and n'/{divisor} > 1); "
                    "fix the preset's buckets or its spdc.num_servers"
                )
        self.config = config
        self._clock = clock
        self._faults_for = faults_for
        self._auto_flush = auto_flush
        self.on_flush = on_flush
        self.on_verdict = on_verdict
        self.on_reject = on_reject
        #: guarded-by: self._lock
        self._queue = MicroBatchQueue(
            max_batch=config.max_batch,
            max_wait_us=config.max_wait_us,
            max_pending=config.max_pending,
        )
        self._results: dict[int, GatewayResult] = {}  #: guarded-by: self._lock
        self._next_rid = 0  #: guarded-by: self._lock
        #: transports this gateway built from TransportConfig specs (its
        #: default spdc.transport or per-request overrides). Owned: the
        #: gateway closes them in close(). Keyed by the frozen config so
        #: equal configs resolve to ONE instance — and therefore one
        #: BucketKey, one bucket, one warm worker pool.
        #: guarded-by: self._lock
        self._owned_transports: dict[TransportConfig, Transport] = {}
        self.stats = GatewayStats()  #: guarded-by: self._lock
        self.metrics = GatewayMetrics()  #: guarded-by: self._lock
        self._admission = AdmissionController(config.admission)  #: guarded-by: self._lock
        self._breakers: dict[BucketKey, CircuitBreaker] = {}  #: guarded-by: self._lock
        #: guarded-by: self._lock
        self._cache = (
            ResultCache(config.cache.max_entries)
            if config.cache.enabled else None
        )
        self._inflight: dict[object, _InFlight] = {}  #: guarded-by: self._lock
        #: (n_bucket, dtype)-keyed warmup/padding dummies, LRU-bounded.
        #: OrderedDict.get + move_to_end MUTATE recency order — every
        #: touch, reads included, must hold the lock (the PR-8 bug).
        #: guarded-by: self._lock
        self._dummies: "OrderedDict[tuple, np.ndarray]" = OrderedDict()
        #: guards queue/results/stats so AsyncSPDCGateway may run sweeps on
        #: a worker thread while the event loop keeps submitting. Held for
        #: bookkeeping only — never across a device sweep.
        self._lock = threading.RLock()

    # -- transports ---------------------------------------------------------

    def _resolve_transport(self, spec):
        """Fold a TransportConfig spec into an owned built instance.

        Names and live Transport instances pass through untouched (names
        resolve later through the shared registry; instances belong to the
        caller). A TransportConfig builds ONCE per distinct config and is
        cached — resolution happens BEFORE bucketing, so two requests
        carrying equal configs key the same bucket and share one warm
        pool. A cached instance someone closed is rebuilt.
        """
        if not isinstance(spec, TransportConfig):
            return spec
        with self._lock:
            t = self._owned_transports.get(spec)
            if t is None or t.closed:
                t = self._owned_transports[spec] = spec.build()
            return t

    def close(self):
        """Close every transport this gateway built (idempotent).

        Only owned instances (resolved from TransportConfig specs) are
        closed — transports the caller passed in live or selected by name
        are the caller's/registry's to manage.
        """
        with self._lock:
            owned, self._owned_transports = self._owned_transports, {}
        for t in owned.values():
            t.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def _key_for(self, n: int, overrides: dict, op: str = "det") -> BucketKey:
        spdc = self.config.spdc
        num_servers = overrides.get("num_servers", spdc.num_servers)
        rateless = overrides.get("rateless", spdc.rateless)
        # rateless sweeps partition into F = overdecompose·N strips, so the
        # bucket size must land on the F-grid, not merely the N-grid
        pad_to = bucket_size_for(
            n, self.config.buckets, _partition_divisor(num_servers, rateless)
        )
        return BucketKey(
            pad_to=pad_to,
            num_servers=num_servers,
            op=op,
            rateless=rateless,
            mode=overrides.get("mode", spdc.mode),
            method=overrides.get("method", spdc.method),
            lambda1=overrides.get("lambda1", spdc.lambda1),
            lambda2=overrides.get("lambda2", spdc.lambda2),
            recover=overrides.get("recover", spdc.recover),
            standby=overrides.get("standby", spdc.standby),
            straggler_deadline=overrides.get(
                "straggler_deadline", spdc.straggler_deadline
            ),
            # resolve_dtype folds spelling variants (np.float32, "float32",
            # jnp dtypes) AND the x64-off float64→float32 resolution into
            # one canonical name — equal compute dtypes must share one
            # bucket, one compiled sweep, and one warmup cache
            dtype=resolve_dtype(overrides.get("dtype", spdc.dtype)).name,
            growth_safe=overrides.get("growth_safe", spdc.growth_safe),
            equilibrate=overrides.get("equilibrate", spdc.equilibrate),
            transport=self._resolve_transport(
                overrides.get("transport", spdc.transport)
            ),
        )

    # -- resilience helpers -------------------------------------------------

    #: requires-lock: self._lock
    def _breaker_for(self, key: BucketKey) -> CircuitBreaker:
        br = self._breakers.get(key)
        if br is None:
            # jitter seed from the key's STABLE fields (a transport
            # instance's id would randomize probe times across runs)
            seed = zlib.crc32(
                f"{key.pad_to}:{key.num_servers}:{key.dtype}:"
                f"{key.mode}:{key.method}:{key.rateless}".encode()
            )
            br = self._breakers[key] = CircuitBreaker(
                self.config.breaker, seed=seed
            )
        return br

    def _cache_key(self, key: BucketKey, tenant: str, matrix: np.ndarray,
                   rhs: np.ndarray | None = None):
        """(BucketKey, tenant, content digest): the BucketKey carries the
        complete security tuple (transport identity AND op), so a hit can
        never cross configs or ops; the digest covers bytes + shape +
        dtype of the matrix — and of the RHS for op="solve", since two
        solves of one matrix against different b are different answers."""
        m = np.ascontiguousarray(matrix)
        h = hashlib.sha256()
        h.update(str(m.shape).encode())
        h.update(str(m.dtype).encode())
        h.update(m.tobytes())
        if rhs is not None:
            b = np.ascontiguousarray(rhs)
            h.update(str(b.shape).encode())
            h.update(str(b.dtype).encode())
            h.update(b.tobytes())
        return (key, tenant, h.digest())

    #: requires-lock: self._lock
    def _reject(self, reason: str, tenant: str, key: BucketKey | None):
        """Record + fire one typed rejection (caller raises afterwards)."""
        ev = RejectEvent(
            reason=reason, tenant=tenant,
            bucket=key.label() if key is not None else None,
        )
        self.metrics.record_reject(ev)
        return ev

    # -- submission ---------------------------------------------------------

    def submit(self, matrix, *, now: float | None = None,
               tenant: str = "default", op: str = "det", rhs=None,
               **overrides) -> int:
        """Enqueue one (n, n) matrix; returns its request id.

        `op` selects the secure-linalg operation (DESIGN.md §12):
          * "det" (default) — the classic determinant sweep;
          * "slogdet" — same sweep, result unpacked as the (sign, logabs)
            pair on GatewayResult (its own buckets/metrics series);
          * "solve" — requires `rhs` of shape (n,) or (n, c); served by a
            per-request verified LinalgSession on the bucket's warm
            transport (solve traffic never shares a sweep with
            determinant traffic, but equal transports mean the SAME warm
            worker pool serves both).

        Rejections are typed and nothing is ever half-enqueued:
          * GatewayOverloaded — the gateway-wide pending queue is full
            (capacity backpressure; retry elsewhere);
          * AdmissionRejected — THIS tenant is over its token-bucket rate
            or pending quota (policy; slow down — the gateway is fine);
          * BreakerOpen — the request's bucket is fast-failing after
            repeated sweep failures (carries a retry_after_s hint; only
            when the breaker config says on_open="fastfail" — "direct"
            detours such requests to the un-coalesced path instead).

        A matrix identical (bytes, security config, tenant) to a
        previously verified one answers from the idempotency cache in
        O(hash); identical submissions already in flight coalesce onto the
        leader's sweep (single-flight). A matrix larger than every bucket
        — or whose synthesized fallback size would exceed the largest
        configured bucket — is served immediately as a direct un-coalesced
        protocol call (stats.direct). Keyword overrides (num_servers,
        mode, method, recover, standby, straggler_deadline, dtype,
        transport) place the request in a bucket matching that
        security/precision/execution config — an f32 client never shares
        a compiled sweep with f64 clients, and an inline sweep never
        coalesces with a multiprocess one.
        """
        unknown = set(overrides) - _OVERRIDE_KEYS
        if unknown:
            # a misspelled security override must fail loudly — silently
            # serving under the gateway defaults would hand the client a
            # weaker config than it asked for
            raise TypeError(
                f"unknown submit() overrides {sorted(unknown)}; "
                f"allowed: {sorted(_OVERRIDE_KEYS)}"
            )
        if op not in _OPS:
            raise ValueError(f"unknown op {op!r}; expected one of {_OPS}")
        matrix = np.asarray(matrix)
        if matrix.ndim != 2 or matrix.shape[0] != matrix.shape[1]:
            raise ValueError(f"expected one square matrix, got {matrix.shape}")
        n = int(matrix.shape[0])
        if n < 2:
            raise ValueError("matrices must be at least 2x2 (KeyGen needs "
                             "n >= 2 blinding elements)")
        if not np.all(np.isfinite(matrix)):
            raise ValueError("matrix contains non-finite entries")
        if op == "solve":
            if rhs is None:
                raise ValueError('op="solve" needs an rhs')
            rhs = np.asarray(rhs)
            if rhs.ndim not in (1, 2) or rhs.shape[0] != n:
                raise ValueError(
                    f"rhs shape {rhs.shape} does not match matrix "
                    f"({n}, {n})"
                )
            if not np.all(np.isfinite(rhs)):
                raise ValueError("rhs contains non-finite entries")
        elif rhs is not None:
            raise ValueError(f'op={op!r} takes no rhs')
        now = self._clock() if now is None else now
        hook_events = []
        try:
            with self._lock:
                try:
                    key = self._key_for(n, overrides, op)
                except NoBucketFits:
                    key = None
                self.metrics.record_submit(tenant)
                # 1. admission: the tenant's token bucket guards the door
                # for EVERY request shape (bucketed, direct, cache hit)
                try:
                    self._admission.charge(tenant, now)
                except AdmissionRejected:
                    self.stats.rejected_admission += 1
                    hook_events.append(
                        ("reject", self._reject("rate", tenant, key)))
                    raise
                rid = self._next_rid
                self._next_rid += 1
                self.stats.submitted += 1
                breaker = None
                probe_granted = False
                req = DetRequest(rid=rid, matrix=matrix, n=n,
                                 enqueued_at=now, tenant=tenant,
                                 op=op, rhs=rhs)
                if key is not None:
                    # 2. idempotency cache / single-flight (cache hits cost
                    # O(hash) — they bypass breaker and quota entirely)
                    if self._cache is not None:
                        req.ckey = self._cache_key(key, tenant, matrix, rhs)
                        hit = self._cache.get(req.ckey)
                        if hit is not None:
                            self.stats.cache_hits += 1
                            self.metrics.counters["cache_hits"] += 1
                            gres = replace(
                                hit, rid=rid, submitted_at=now,
                                completed_at=now, flush_reason="cache",
                                batch=1, recovery=None, cache_hit=True,
                                tenant=tenant,
                            )
                            self.metrics.counters["admitted"] += 1
                            hook_events.append(("verdict", self._deliver(
                                gres, key.label())))
                            return rid
                        self.stats.cache_misses += 1
                        self.metrics.counters["cache_misses"] += 1
                        if self.config.cache.single_flight:
                            entry = self._inflight.get(req.ckey)
                            if entry is not None:
                                # ride the leader's sweep; quota still holds
                                # a slot (the follower occupies memory and a
                                # waiter until delivery)
                                try:
                                    self._admission.acquire_slot(tenant)
                                except AdmissionRejected:
                                    self.stats.submitted -= 1
                                    self.stats.rejected_admission += 1
                                    hook_events.append(
                                        ("reject",
                                         self._reject("quota", tenant, key)))
                                    raise
                                entry.followers.append(req)
                                self.stats.coalesced += 1
                                self.metrics.counters["coalesced"] += 1
                                self.metrics.counters["admitted"] += 1
                                return rid
                    # 3. circuit breaker: a poisoned bucket fast-fails or
                    # detours instead of poisoning a shared sweep
                    breaker = self._breaker_for(key)
                    verdict = breaker.allow(now)
                    if verdict == "open":
                        if self.config.breaker.on_open == "direct":
                            self.stats.degraded_direct += 1
                            key = None  # detour: served, but un-coalesced
                        else:
                            self.stats.submitted -= 1
                            self.stats.rejected_breaker += 1
                            hook_events.append(
                                ("reject",
                                 self._reject("breaker", tenant, key)))
                            raise BreakerOpen(
                                f"bucket {key.label()} is fast-failing "
                                "after repeated sweep failures; retry in "
                                f"{breaker.retry_after(now):.3f}s",
                                bucket=key.label(),
                                retry_after_s=breaker.retry_after(now),
                            )
                    elif verdict == "probe":
                        probe_granted = True
                        self.stats.breaker_probes += 1
                        self.metrics.counters["breaker_probes"] += 1
                if key is not None:
                    # 4. per-tenant pending quota, then the gateway-wide
                    # capacity door; BOTH unwind completely on rejection —
                    # including a just-granted half-open probe, which must
                    # return to "open" (with next_probe_at already in the
                    # past) or no flush would ever record() and the bucket
                    # would fast-fail forever
                    try:
                        self._admission.acquire_slot(tenant)
                    except AdmissionRejected:
                        if probe_granted:
                            breaker.revert_probe()
                        self.stats.submitted -= 1
                        self.stats.rejected_admission += 1
                        hook_events.append(
                            ("reject", self._reject("quota", tenant, key)))
                        raise
                    try:
                        full = self._queue.push(key, req)
                    except GatewayOverloaded:
                        if probe_granted:
                            breaker.revert_probe()
                        self._admission.release_slot(tenant)
                        self.stats.submitted -= 1
                        self.stats.rejected += 1
                        hook_events.append(
                            ("reject", self._reject("overload", tenant, key)))
                        raise
                    if req.ckey is not None and self.config.cache.single_flight:
                        self._inflight[req.ckey] = _InFlight(rid)
                self.metrics.counters["admitted"] += 1
        finally:
            self._fire(hook_events)
        if key is None:
            self._run_direct(req, overrides, now)
        elif full and self._auto_flush:
            self._flush(key, "full", now)
        return rid

    # -- flushing -----------------------------------------------------------

    def poll(self, now: float | None = None) -> list[GatewayResult]:
        """Flush every due bucket (full, or past the wait budget) and
        return the newly completed results."""
        now = self._clock() if now is None else now
        out: list[GatewayResult] = []
        while True:
            with self._lock:
                due = self._queue.due(now)
            if not due:
                return out
            for key, reason in due:
                out.extend(self._flush(key, reason, now))

    def drain(self) -> list[GatewayResult]:
        """Flush every bucket regardless of policy (shutdown / test sync),
        still in max_batch chunks so sweeps reuse warm shapes."""
        now = self._clock()
        out: list[GatewayResult] = []
        while True:
            with self._lock:
                keys = self._queue.keys()
            if not keys:
                return out
            for key in keys:
                out.extend(self._flush(key, "drain", now))

    def next_deadline(self, now: float | None = None) -> float | None:
        """Seconds until the earliest pending flush deadline (the async
        flusher's sleep bound); None when no requests are queued."""
        now = self._clock() if now is None else now
        with self._lock:
            return self._queue.next_deadline(now)

    def has_full_bucket(self) -> bool:
        with self._lock:
            return self._queue.has_full()

    @property
    def pending(self) -> int:
        return self._queue.pending

    def take(self, rid: int) -> GatewayResult | None:
        """Claim a completed result (None while its bucket is pending)."""
        with self._lock:
            return self._results.pop(rid, None)

    #: requires-lock: self._lock
    def _deliver(self, gres: GatewayResult, bucket_label: str | None):
        """Store one finished result + its bookkeeping (lock held).

        Returns the VerdictEvent for the caller's hook batch."""
        assert_owns_lock(self._lock, "gateway results/metrics")
        self._results[gres.rid] = gres
        ev = VerdictEvent(
            rid=gres.rid, bucket=bucket_label, tenant=gres.tenant,
            verified=gres.verified, latency_s=gres.latency_s,
            flush_reason=gres.flush_reason, cache_hit=gres.cache_hit,
            error=gres.error,
        )
        self.metrics.record_verdict(ev)
        return ev

    def _fire(self, hook_events) -> None:
        """Invoke observer hooks OUTSIDE the gateway lock."""
        for kind, ev in hook_events:
            hook = {"flush": self.on_flush, "verdict": self.on_verdict,
                    "reject": self.on_reject}[kind]
            if hook is not None:
                hook(ev)

    #: requires-lock: self._lock
    def _followers_of(self, req: DetRequest) -> list[DetRequest]:
        """Pop the single-flight followers riding this leader (lock held)."""
        if req.ckey is None:
            return []
        entry = self._inflight.pop(req.ckey, None)
        if entry is None or entry.leader_rid != req.rid:
            # a follower of an older leader re-registered under a new one;
            # only the true leader's completion pops the entry
            if entry is not None:
                self._inflight[req.ckey] = entry
            return []
        return entry.followers

    def _flush(self, key: BucketKey, reason: str, now: float):
        with self._lock:
            reqs = self._queue.pop(key, limit=self.config.max_batch)
            if not reqs:
                return []
            self.stats.flushes += 1
            if reason == "full":
                self.stats.flushes_full += 1
            elif reason == "timeout":
                self.stats.flushes_timeout += 1
            else:
                self.stats.flushes_drain += 1
        if key.op == "solve":
            return self._flush_solve(key, reqs, reason, now)
        mats = [r.matrix for r in reqs]
        sweep_t0 = self._clock()
        try:
            # padding runs inside the try: the requests are already popped
            # from the queue, so a padding failure must fail THEM (below),
            # not vanish them and hang their waiters
            if self.config.pad_batches:
                target = next(
                    b for b in allowed_batch_sizes(self.config.max_batch)
                    if b >= len(mats)
                )
                mats = mats + [
                    self._dummy(key.pad_to, key.dtype)
                    for _ in range(target - len(mats))
                ]
            faults = self._faults_for(key) if self._faults_for else None
            res = outsource_determinant_mixed(
                mats,
                key.num_servers,
                faults=faults,
                **key.protocol_kwargs(),
            )
        except Exception as e:  # noqa: BLE001 — fail the requests, not the service
            # the bucket is already popped: every co-batched request gets
            # its own failed result instead of vanishing (and the async
            # flusher keeps running)
            return self._fail_requests(
                reqs, key, reason, f"{type(e).__name__}: {e}",
                flush_now=now, sweep_t0=sweep_t0, padded_batch=len(mats),
            )
        done = self._clock()
        label = key.label()
        out = []
        hook_events = []
        with self._lock:
            if res.report.recovery is not None:
                self.stats.recovered_flushes += 1
            n_verified = sum(
                1 for i in range(len(reqs)) if bool(res.verified[i])
            )
            unverified_rate = 1.0 - n_verified / len(reqs)
            self._record_breaker(key, now=done, failed=False,
                                 unverified_rate=unverified_rate)
            flush_ev = FlushEvent(
                bucket=label, reason=reason, batch=len(reqs),
                padded_batch=len(mats),
                queue_waits_s=tuple(now - r.enqueued_at for r in reqs),
                sweep_s=done - sweep_t0,
                recovered=res.report.recovery is not None,
            )
            self.metrics.record_flush(flush_ev)
            hook_events.append(("flush", flush_ev))
            for i, req in enumerate(reqs):
                det = res.dets[i]
                gres = GatewayResult(
                    rid=req.rid,
                    det=det,
                    verified=bool(res.verified[i]),
                    residual=float(res.residual[i]),
                    n=req.n,
                    pad_to=key.pad_to,
                    batch=len(reqs),
                    flush_reason=reason,
                    submitted_at=req.enqueued_at,
                    completed_at=done,
                    recovery=res.report.recovery,
                    tenant=req.tenant,
                    op=key.op,
                    # slogdet answers in the overflow-safe pair the client
                    # asked for; .value would overflow exactly where the
                    # protocol's log-space arithmetic was built to survive
                    sign=float(det.sign) if key.op == "slogdet" else None,
                    logabs=float(det.logabs) if key.op == "slogdet" else None,
                )
                hook_events.append(("verdict", self._deliver(gres, label)))
                out.append(gres)
                self.stats.served += 1
                self._admission.release_slot(req.tenant)
                # cache-aside: ONLY verified results (a rejected verdict
                # must not outlive its sweep), stored before followers so
                # late identical submissions hit instead of re-leading
                if (req.ckey is not None and self._cache is not None
                        and gres.verified and gres.error is None):
                    self._cache.put(req.ckey, gres)
                for f in self._followers_of(req):
                    fres = replace(
                        gres, rid=f.rid, submitted_at=f.enqueued_at,
                        flush_reason="coalesced", tenant=f.tenant,
                    )
                    hook_events.append(("verdict", self._deliver(fres, label)))
                    out.append(fres)
                    self.stats.served += 1
                    self._admission.release_slot(f.tenant)
        self._fire(hook_events)
        return out

    def _flush_solve(self, key: BucketKey, reqs, reason: str, now: float):
        """op="solve" flush engine: one verified LinalgSession per request.

        Solve requests carry private RHS payloads and run blinded
        triangular-solve rounds against a per-matrix verified LU — there
        is no batched sweep to coalesce them into (and pad_batches does
        not apply). They still flow through the same bucket/flush
        machinery so they inherit the breaker, cache, metrics, and the
        bucket's WARM transport: a solve bucket and a det bucket keyed to
        the same transport instance share one worker pool.

        Failures are per-request: one rejected session fails that request
        alone; the breaker sees the flush's unverified rate.
        """
        from repro.linalg import outsource_solve

        sweep_t0 = self._clock()
        faults = self._faults_for(key) if self._faults_for else None
        outcomes = []  # (req, solution, residual, recovery, healed, error)
        for req in reqs:
            try:
                y, s = outsource_solve(req.matrix, req.rhs, key.num_servers,
                                       faults=faults, **key.linalg_kwargs())
                rep = s.report
                residual = max(
                    (float(o.residual) for o in rep.ops), default=0.0
                )
                outcomes.append((req, y, residual, rep.recovery, None))
            except Exception as e:  # noqa: BLE001 — fail the request, not the flush
                outcomes.append(
                    (req, None, float("nan"), None,
                     f"{type(e).__name__}: {e}")
                )
        done = self._clock()
        label = key.label()
        out = []
        hook_events = []
        with self._lock:
            n_failed = sum(1 for o in outcomes if o[4] is not None)
            if any(o[3] is not None for o in outcomes):
                self.stats.recovered_flushes += 1
            self._record_breaker(
                key, now=done, failed=n_failed == len(reqs),
                unverified_rate=n_failed / len(reqs),
            )
            flush_ev = FlushEvent(
                bucket=label, reason=reason, batch=len(reqs),
                padded_batch=len(reqs),
                queue_waits_s=tuple(now - r.enqueued_at for r in reqs),
                sweep_s=done - sweep_t0,
                recovered=any(o[3] is not None for o in outcomes),
            )
            self.metrics.record_flush(flush_ev)
            hook_events.append(("flush", flush_ev))
            for req, y, residual, recovery, error in outcomes:
                ok = error is None
                gres = GatewayResult(
                    rid=req.rid,
                    det=None,
                    verified=ok,
                    residual=residual,
                    n=req.n,
                    pad_to=key.pad_to,
                    batch=len(reqs),
                    flush_reason=reason,
                    submitted_at=req.enqueued_at,
                    completed_at=done,
                    recovery=recovery,
                    error=error,
                    tenant=req.tenant,
                    op="solve",
                    solution=y,
                )
                hook_events.append(("verdict", self._deliver(gres, label)))
                out.append(gres)
                if ok:
                    self.stats.served += 1
                else:
                    self.stats.failed += 1
                self._admission.release_slot(req.tenant)
                if (req.ckey is not None and self._cache is not None
                        and ok):
                    self._cache.put(req.ckey, gres)
                for f in self._followers_of(req):
                    fres = replace(
                        gres, rid=f.rid, submitted_at=f.enqueued_at,
                        flush_reason="coalesced", tenant=f.tenant,
                    )
                    hook_events.append(("verdict", self._deliver(fres, label)))
                    out.append(fres)
                    if ok:
                        self.stats.served += 1
                    else:
                        self.stats.failed += 1
                    self._admission.release_slot(f.tenant)
        self._fire(hook_events)
        return out

    #: requires-lock: self._lock
    def _record_breaker(self, key: BucketKey, *, now: float, failed: bool,
                        unverified_rate: float = 0.0) -> None:
        """Feed a flush outcome to the bucket's breaker (lock held)."""
        breaker = self._breaker_for(key)
        before = breaker.state
        after = breaker.record(now, failed=failed,
                               unverified_rate=unverified_rate)
        if after == "open" and before != "open":
            self.stats.breaker_opens += 1
            self.metrics.counters["breaker_opens"] += 1
        elif before == "half_open" and after == "closed":
            self.stats.breaker_closes += 1
            self.metrics.counters["breaker_closes"] += 1

    def _fail_requests(self, reqs, key: BucketKey, reason: str, error: str,
                       *, flush_now: float | None = None,
                       sweep_t0: float | None = None,
                       padded_batch: int | None = None):
        """Deliver a per-request failure result for a sweep that raised."""
        done = self._clock()
        label = key.label()
        out = []
        hook_events = []
        with self._lock:
            if reason != "direct":
                self._record_breaker(key, now=done, failed=True)
                flush_ev = FlushEvent(
                    bucket=label, reason=reason, batch=len(reqs),
                    padded_batch=padded_batch or len(reqs),
                    queue_waits_s=tuple(
                        (flush_now if flush_now is not None else done)
                        - r.enqueued_at for r in reqs
                    ),
                    sweep_s=done - (sweep_t0 if sweep_t0 is not None else done),
                    error=error,
                )
                self.metrics.record_flush(flush_ev)
                hook_events.append(("flush", flush_ev))
            self.stats.failed += len(reqs)
            for req in reqs:
                gres = GatewayResult(
                    rid=req.rid,
                    det=None,
                    verified=False,
                    residual=float("nan"),
                    n=req.n,
                    pad_to=key.pad_to,
                    batch=len(reqs),
                    flush_reason=reason,
                    submitted_at=req.enqueued_at,
                    completed_at=done,
                    error=error,
                    tenant=req.tenant,
                    op=req.op,
                )
                hook_events.append(("verdict", self._deliver(
                    gres, label if reason != "direct" else None)))
                out.append(gres)
                if reason != "direct":
                    self._admission.release_slot(req.tenant)
                # single-flight followers fail WITH their leader — a
                # stranded follower would hang an async waiter forever
                for f in self._followers_of(req):
                    fres = replace(
                        gres, rid=f.rid, submitted_at=f.enqueued_at,
                        tenant=f.tenant,
                    )
                    hook_events.append(("verdict", self._deliver(
                        fres, label if reason != "direct" else None)))
                    out.append(fres)
                    self.stats.failed += 1
                    self._admission.release_slot(f.tenant)
        self._fire(hook_events)
        return out

    def _run_direct(self, req: DetRequest, overrides: dict, now: float):
        """Oversize / breaker-detour escape hatch: one un-coalesced call.

        Op-aware like the flush path: solve requests run their own
        LinalgSession, slogdet unpacks the Determinant's overflow-safe
        pair, det stays the classic protocol call.
        """
        from repro.core.protocol import outsource_determinant

        spdc = self.config.spdc
        transport = self._resolve_transport(
            overrides.get("transport", spdc.transport)
        )
        try:
            if req.op == "solve":
                from repro.linalg import outsource_solve

                method = overrides.get("method", spdc.method)
                y, s = outsource_solve(
                    req.matrix,
                    req.rhs,
                    overrides.get("num_servers", spdc.num_servers),
                    transport=transport,
                    mode=overrides.get("mode", spdc.mode),
                    # same q3→q2 promotion as BucketKey.linalg_kwargs
                    method="q2" if method == "q3" else method,
                    lambda1=overrides.get("lambda1", spdc.lambda1),
                    lambda2=overrides.get("lambda2", spdc.lambda2),
                    recover=overrides.get("recover", spdc.recover),
                    standby=overrides.get("standby", spdc.standby),
                    dtype=overrides.get("dtype", spdc.dtype),
                    growth_safe=overrides.get(
                        "growth_safe", spdc.growth_safe
                    ),
                )
                rep = s.report
                det = None
                verified = True
                residual = max(
                    (float(o.residual) for o in rep.ops), default=0.0
                )
                padding = s.padding
                recovery = rep.recovery
            else:
                res = outsource_determinant(
                    req.matrix,
                    overrides.get("num_servers", spdc.num_servers),
                    mode=overrides.get("mode", spdc.mode),
                    method=overrides.get("method", spdc.method),
                    lambda1=overrides.get("lambda1", spdc.lambda1),
                    lambda2=overrides.get("lambda2", spdc.lambda2),
                    recover=overrides.get("recover", spdc.recover),
                    standby=overrides.get("standby", spdc.standby),
                    straggler_deadline=overrides.get(
                        "straggler_deadline", spdc.straggler_deadline
                    ),
                    dtype=overrides.get("dtype", spdc.dtype),
                    growth_safe=overrides.get("growth_safe", spdc.growth_safe),
                    equilibrate=overrides.get("equilibrate", spdc.equilibrate),
                    transport=transport,
                    rateless=overrides.get("rateless", spdc.rateless),
                )
                y = None
                det = res.det
                verified = res.verified
                residual = res.residual
                padding = res.padding
                recovery = res.report.recovery
        except Exception as e:  # noqa: BLE001 — fail the request, not the service
            key = BucketKey(pad_to=req.n, num_servers=spdc.num_servers,
                            op=req.op, rateless=spdc.rateless)
            self._fail_requests([req], key, "direct",
                                f"{type(e).__name__}: {e}")
            return
        hook_events = []
        with self._lock:
            self.stats.direct += 1
            self.metrics.counters["direct"] += 1
            gres = GatewayResult(
                rid=req.rid,
                det=det,
                verified=verified,
                residual=residual,
                n=req.n,
                pad_to=req.n + padding,
                batch=1,
                flush_reason="direct",
                submitted_at=req.enqueued_at,
                completed_at=self._clock(),
                recovery=recovery,
                tenant=req.tenant,
                op=req.op,
                sign=float(det.sign) if req.op == "slogdet" else None,
                logabs=float(det.logabs) if req.op == "slogdet" else None,
                solution=y,
            )
            hook_events.append(("verdict", self._deliver(gres, None)))
        self._fire(hook_events)

    def _dummy(self, n_bucket: int, dtype: str = "float64") -> np.ndarray:
        """Client-profile filler matrix for batch padding: diag-dominant
        noise, cached per (bucket size, dtype) with an LRU bound. (A bare
        scaled identity would rotate to an exactly singular anti-diagonal
        under the cipher's PRT stage — fillers must look like real client
        matrices.) dtype is part of the key so an f32 bucket warms and
        pads with f32 fillers — the exact matrix profile its sweeps see —
        and the bound keeps a long-lived gateway serving a diverse mix
        from accumulating one full matrix per distinct bucket forever.
        The result is discarded; it exists so the sweep runs at a warmed
        batch shape."""
        ckey = (n_bucket, str(dtype))
        with self._lock:  # RLock: safe from flush (unlocked) and warmup
            assert_owns_lock(self._lock, "_dummies LRU")
            cached = self._dummies.get(ckey)
            if cached is None:
                rng = np.random.default_rng(n_bucket)
                cached = (
                    rng.standard_normal((n_bucket, n_bucket))
                    + n_bucket * np.eye(n_bucket)
                ).astype(np.dtype(str(dtype)))
                self._dummies[ckey] = cached
                while len(self._dummies) > _DUMMY_CACHE_MAX:
                    self._dummies.popitem(last=False)
            else:
                self._dummies.move_to_end(ckey)
        return cached

    # -- observability ------------------------------------------------------

    def metrics_snapshot(self):
        """Point-in-time MetricsSnapshot: counters + quantiles from the
        registry, live gauges (queue depth, breaker states, cache size,
        tenant pending) folded in from the serving structures."""
        with self._lock:
            bucket_gauges: dict[str, dict] = {}
            for key, depth in self._queue.depth_by_key().items():
                bucket_gauges.setdefault(key.label(), {})["depth"] = depth
            for key, br in self._breakers.items():
                bucket_gauges.setdefault(key.label(), {})["breaker"] = br.state
            return self.metrics.snapshot(gauges={
                "pending": self._queue.pending,
                "buckets": bucket_gauges,
                "tenant_pending": self._admission.pending_by_tenant(),
                "cache_entries": len(self._cache) if self._cache else 0,
                "cache_evictions": self._cache.evictions if self._cache else 0,
            })

    def healthz(self) -> dict:
        """Health verdict dict (the /healthz body): ok | degraded (open
        breaker) | overloaded (pending at the backpressure bound)."""
        return render_healthz(
            self.metrics_snapshot(), max_pending=self.config.max_pending
        )

    def render_metrics(self) -> str:
        """Prometheus-style text exposition (the /metrics body)."""
        return render_prometheus(self.metrics_snapshot())

    def breaker_state(self, key: BucketKey) -> str:
        """Current breaker state for a bucket ("closed" when never used)."""
        with self._lock:
            br = self._breakers.get(key)
            return br.state if br is not None else "closed"

    # -- warmup -------------------------------------------------------------

    def warmup(self, batch_sizes: tuple[int, ...] | None = None) -> int:
        """Pre-compile each bucket's sweep at the given batch sizes.

        The coalesced sweep jit-compiles per (B, n', N, fault-plan) shape;
        a cold bucket's first flush would otherwise pay seconds of XLA
        compilation in a client's latency. The default shape set is
        exactly what pad_batches can produce (allowed_batch_sizes), so a
        warmed gateway never compiles during a flush. Returns the number
        of programs compiled. Runs the protocol sweep directly on
        well-conditioned dummy matrices — results are discarded and the
        serving queue/stats are never touched.
        """
        sizes = batch_sizes or self.config.warmup_batches
        if not sizes:
            sizes = (
                allowed_batch_sizes(self.config.max_batch)
                if self.config.pad_batches
                else (self.config.max_batch,)
            )
        compiled = 0
        # every configured bucket is servable — __init__ validates the
        # preset against spdc.num_servers and raises otherwise
        for n_bucket in self.config.buckets:
            key = self._key_for(n_bucket, {})
            for b in sizes:
                # the same cached filler live batch padding uses, so warmup
                # compiles against the exact matrix profile flushes see
                dummies = [self._dummy(n_bucket, key.dtype)] * b
                res = outsource_determinant_mixed(
                    dummies, key.num_servers, **key.protocol_kwargs()
                )
                assert bool(np.all(res.verified))
                compiled += 1
        return compiled


class AsyncSPDCGateway:
    """asyncio front-end: ``await submit(m)`` → GatewayResult.

    A background flusher task wakes on the earliest flush deadline (or
    immediately when a bucket fills) and runs the device sweep in a worker
    thread, so the event loop keeps accepting submissions while the
    servers factor the previous batch. Use as an async context manager:

        async with AsyncSPDCGateway(cfg) as gw:
            results = await asyncio.gather(*(gw.submit(m) for m in ms))

    Typed rejections (GatewayOverloaded / AdmissionRejected / BreakerOpen)
    propagate out of ``submit`` immediately — the future never enters the
    waiter table, so a rejection storm cannot leak futures
    (tests/test_overload.py asserts this).
    """

    def __init__(self, config: SPDCGatewayConfig = SPDC_GATEWAY_DEFAULT,
                 **kwargs):
        kwargs.setdefault("auto_flush", False)
        self._gw = SPDCGateway(config, **kwargs)
        self._waiters: dict[int, object] = {}
        self._task = None
        self._kick = None
        self._closed = False

    @property
    def stats(self) -> GatewayStats:
        return self._gw.stats

    @property
    def pending(self) -> int:
        return self._gw.pending

    def metrics_snapshot(self):
        return self._gw.metrics_snapshot()

    def healthz(self) -> dict:
        return self._gw.healthz()

    def render_metrics(self) -> str:
        return self._gw.render_metrics()

    async def __aenter__(self):
        import asyncio

        self._kick = asyncio.Event()
        self._task = asyncio.create_task(self._flusher())
        return self

    async def __aexit__(self, *exc):
        await self.aclose()

    async def aclose(self):
        import asyncio

        self._closed = True
        if self._task is not None:
            self._kick.set()
            await self._task
            self._task = None
        if self._gw.pending:
            await asyncio.to_thread(self._gw.drain)
            self._deliver()
        # release owned transports (worker pools, socket daemons) after
        # the final drain so shutdown is deterministic, not GC-timed
        await asyncio.to_thread(self._gw.close)

    async def warmup(self, batch_sizes: tuple[int, ...] | None = None) -> int:
        """Pre-compile bucket sweeps off the event loop (SPDCGateway.warmup)."""
        import asyncio

        return await asyncio.to_thread(self._gw.warmup, batch_sizes)

    async def submit(self, matrix, *, tenant: str = "default",
                     op: str = "det", rhs=None, **overrides) -> GatewayResult:
        """Enqueue one matrix and wait for its bucket's sweep.

        `op`/`rhs` select the secure-linalg operation exactly as on
        SPDCGateway.submit. Raises GatewayOverloaded / AdmissionRejected /
        BreakerOpen immediately (without queueing) when the gateway sheds
        the request.
        """
        import asyncio

        if self._task is None:
            raise RuntimeError("use `async with AsyncSPDCGateway(...)`")
        # to_thread keeps the event loop free even when submit() itself
        # does device work (the oversize direct-call escape hatch)
        rid = await asyncio.to_thread(
            self._gw.submit, matrix, tenant=tenant, op=op, rhs=rhs,
            **overrides
        )
        ready = self._gw.take(rid)
        if ready is not None:  # direct call or cache hit completed inline
            return ready
        fut = asyncio.get_running_loop().create_future()
        self._waiters[rid] = fut
        self._kick.set()
        if self._closed:
            # aclose() may have drained before our enqueue landed (its
            # pending check raced our to_thread); flush ourselves so this
            # future cannot be stranded
            await asyncio.to_thread(self._gw.drain)
            self._deliver()
        return await fut

    def _deliver(self):
        for rid in list(self._waiters):
            res = self._gw.take(rid)
            if res is None:
                continue
            fut = self._waiters.pop(rid)
            if not fut.done():
                fut.set_result(res)

    async def _flusher(self):
        import asyncio

        while not self._closed:
            deadline = self._gw.next_deadline()
            if not self._gw.has_full_bucket():
                timeout = deadline if deadline is not None else 0.5
                try:
                    await asyncio.wait_for(
                        self._kick.wait(), timeout=max(timeout, 1e-4)
                    )
                except asyncio.TimeoutError:
                    pass
                self._kick.clear()
                if self._closed:
                    break
            if self._gw.pending:
                # _flush already converts sweep failures into per-request
                # error results; anything else must not kill the flusher
                # (every later submission would hang on a dead task)
                try:
                    await asyncio.to_thread(self._gw.poll)
                except Exception:  # noqa: BLE001
                    pass
                self._deliver()
