"""SPDC edge gateway — async micro-batching determinant service.

This is the layer that turns the protocol reproduction into a *service*
(ROADMAP north star; DESIGN.md §5): many clients each submit one matrix;
the gateway coalesces them into the batched protocol sweeps that PR 1 made
fast and PR 2 made fault-tolerant.

    client ──submit(M)──▶ gateway ──bucket by (n', security config)──▶
      ┌───────────────┐   flush on max_batch / max_wait_us
      │ bucket n'=64  │──▶ ONE outsource_determinant_mixed sweep
      │ bucket n'=256 │──▶   (one cipher+augment per request, one jitted
      └───────────────┘      N-server LU, one batched verify, per-request
                             Decipher) ──▶ per-request GatewayResult

Two surfaces:

  * ``SPDCGateway`` — the synchronous engine. `submit()` enqueues (and by
    default flushes a bucket the instant it fills), `poll(now)` flushes
    buckets whose oldest request exceeded the wait budget, `drain()`
    flushes everything. The clock is injected, so tests drive flush
    policy with virtual time.
  * ``AsyncSPDCGateway`` — the asyncio service: ``await submit(m)``
    resolves to that request's GatewayResult; a background flusher task
    runs the device sweeps off the event loop thread.

Faults and recovery are per-bucket: a tampering server poisons only the
sweeps it participates in, and when a bucket's security config says
`recover=True`, the verification-driven re-dispatch (DESIGN.md §4) heals
that bucket's batch alone — co-batched requests in other buckets never
pay for it (test_gateway.py::test_tampered_bucket_isolated).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass

import numpy as np

from repro.api.transport import Transport, TransportConfig
from repro.configs.spdc import SPDC_GATEWAY_DEFAULT, SPDCGatewayConfig
from repro.core.protocol import outsource_determinant_mixed, resolve_dtype

from .queue import (
    BucketKey,
    DetRequest,
    GatewayOverloaded,
    GatewayStats,
    MicroBatchQueue,
    NoBucketFits,
    bucket_size_for,
)

__all__ = [
    "GatewayResult",
    "SPDCGateway",
    "AsyncSPDCGateway",
    "GatewayOverloaded",
]

#: per-request security-config overrides submit() accepts (the BucketKey
#: fields minus pad_to, which bucketing derives)
_OVERRIDE_KEYS = frozenset(
    {"num_servers", "mode", "method", "lambda1", "lambda2", "recover",
     "standby", "straggler_deadline", "dtype", "growth_safe",
     "equilibrate", "transport", "rateless"}
)


def _partition_divisor(num_servers: int, rateless: bool) -> int:
    """The strip count a padded size must divide into: N for deadline-based
    sweeps, F = overdecompose·N for rateless ones (the bucket grid has to
    accommodate the over-decomposed partition, not just the fleet size)."""
    if not rateless:
        return num_servers
    from repro.configs.spdc import RATELESS_DEFAULT

    return num_servers * RATELESS_DEFAULT.overdecompose


def allowed_batch_sizes(max_batch: int) -> tuple[int, ...]:
    """The bounded set of sweep batch shapes under pad_batches: powers of
    two up to max_batch, plus max_batch itself."""
    sizes = []
    b = 1
    while b < max_batch:
        sizes.append(b)
        b *= 2
    sizes.append(max_batch)
    return tuple(sizes)


@dataclass
class GatewayResult:
    """One client request's outcome, unpacked from its bucket's sweep.

    `error` is set (with det=None, verified=False) when the request's
    sweep raised instead of completing — co-batched requests each get
    their own failed result rather than disappearing.
    """

    rid: int
    det: object  # core.decipher.Determinant (None when error is set)
    verified: bool
    residual: float
    n: int  # client's raw matrix size
    pad_to: int  # bucket size the sweep ran at (== n for direct calls)
    batch: int  # how many requests shared the sweep
    flush_reason: str  # "full" | "timeout" | "drain" | "direct"
    submitted_at: float
    completed_at: float
    recovery: object | None = None  # bucket's RecoveryReport, if it healed
    error: str | None = None  # sweep failure, delivered per-request

    @property
    def latency_s(self) -> float:
        return self.completed_at - self.submitted_at


class SPDCGateway:
    """Synchronous micro-batching engine (see module docstring).

    config: an SPDCGatewayConfig preset (configs.spdc). Its `spdc` field
        supplies each request's default security config; `submit()`
        keyword overrides open separate buckets.
    clock: monotonic-seconds source; injectable for deterministic tests.
    faults_for: optional hook BucketKey -> FaultPlan | None injecting
        misbehaving servers into chosen buckets' sweeps (benchmarks and
        fault-isolation tests; a real deployment has real faults).
    auto_flush: flush a bucket synchronously inside submit() the moment it
        reaches max_batch. AsyncSPDCGateway disables this so sweeps always
        run on its flusher thread.
    """

    def __init__(
        self,
        config: SPDCGatewayConfig = SPDC_GATEWAY_DEFAULT,
        *,
        clock=time.monotonic,
        faults_for=None,
        auto_flush: bool = True,
    ):
        if not config.buckets:
            raise ValueError("gateway config needs at least one bucket size")
        # validate the preset bucket list against the default server count
        # up front, naming the offending bucket: a bucket that fails the
        # schedule's divisibility rule is a config bug, and catching it at
        # construction beats every request of that size silently riding
        # the synthesized-fallback (or, pre-fix, the direct) path
        divisor = _partition_divisor(
            config.spdc.num_servers, config.spdc.rateless
        )
        for b in config.buckets:
            if b % divisor != 0 or b // divisor <= 1:
                raise ValueError(
                    f"bucket {b} in {tuple(config.buckets)} is not "
                    f"servable by num_servers={config.spdc.num_servers}"
                    + (" under rateless over-decomposition"
                       if config.spdc.rateless else "")
                    + f" (need n' % {divisor} == 0 and n'/{divisor} > 1); "
                    "fix the preset's buckets or its spdc.num_servers"
                )
        self.config = config
        self._clock = clock
        self._faults_for = faults_for
        self._auto_flush = auto_flush
        self._queue = MicroBatchQueue(
            max_batch=config.max_batch,
            max_wait_us=config.max_wait_us,
            max_pending=config.max_pending,
        )
        self._results: dict[int, GatewayResult] = {}
        self._next_rid = 0
        #: transports this gateway built from TransportConfig specs (its
        #: default spdc.transport or per-request overrides). Owned: the
        #: gateway closes them in close(). Keyed by the frozen config so
        #: equal configs resolve to ONE instance — and therefore one
        #: BucketKey, one bucket, one warm worker pool.
        self._owned_transports: dict[TransportConfig, Transport] = {}
        self.stats = GatewayStats()
        #: guards queue/results/stats so AsyncSPDCGateway may run sweeps on
        #: a worker thread while the event loop keeps submitting. Held for
        #: bookkeeping only — never across a device sweep.
        self._lock = threading.RLock()

    # -- submission ---------------------------------------------------------

    def _resolve_transport(self, spec):
        """Fold a TransportConfig spec into an owned built instance.

        Names and live Transport instances pass through untouched (names
        resolve later through the shared registry; instances belong to the
        caller). A TransportConfig builds ONCE per distinct config and is
        cached — resolution happens BEFORE bucketing, so two requests
        carrying equal configs key the same bucket and share one warm
        pool. A cached instance someone closed is rebuilt.
        """
        if not isinstance(spec, TransportConfig):
            return spec
        with self._lock:
            t = self._owned_transports.get(spec)
            if t is None or t.closed:
                t = self._owned_transports[spec] = spec.build()
            return t

    def close(self):
        """Close every transport this gateway built (idempotent).

        Only owned instances (resolved from TransportConfig specs) are
        closed — transports the caller passed in live or selected by name
        are the caller's/registry's to manage.
        """
        with self._lock:
            owned, self._owned_transports = self._owned_transports, {}
        for t in owned.values():
            t.close()

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
        return False

    def _key_for(self, n: int, overrides: dict) -> BucketKey:
        spdc = self.config.spdc
        num_servers = overrides.get("num_servers", spdc.num_servers)
        rateless = overrides.get("rateless", spdc.rateless)
        # rateless sweeps partition into F = overdecompose·N strips, so the
        # bucket size must land on the F-grid, not merely the N-grid
        pad_to = bucket_size_for(
            n, self.config.buckets, _partition_divisor(num_servers, rateless)
        )
        return BucketKey(
            pad_to=pad_to,
            num_servers=num_servers,
            rateless=rateless,
            mode=overrides.get("mode", spdc.mode),
            method=overrides.get("method", spdc.method),
            lambda1=overrides.get("lambda1", spdc.lambda1),
            lambda2=overrides.get("lambda2", spdc.lambda2),
            recover=overrides.get("recover", spdc.recover),
            standby=overrides.get("standby", spdc.standby),
            straggler_deadline=overrides.get(
                "straggler_deadline", spdc.straggler_deadline
            ),
            # resolve_dtype folds spelling variants (np.float32, "float32",
            # jnp dtypes) AND the x64-off float64→float32 resolution into
            # one canonical name — equal compute dtypes must share one
            # bucket, one compiled sweep, and one warmup cache
            dtype=resolve_dtype(overrides.get("dtype", spdc.dtype)).name,
            growth_safe=overrides.get("growth_safe", spdc.growth_safe),
            equilibrate=overrides.get("equilibrate", spdc.equilibrate),
            transport=self._resolve_transport(
                overrides.get("transport", spdc.transport)
            ),
        )

    def submit(self, matrix, *, now: float | None = None, **overrides) -> int:
        """Enqueue one (n, n) matrix; returns its request id.

        Raises GatewayOverloaded when max_pending requests are already
        queued (backpressure — nothing is enqueued). A matrix larger than
        every bucket — or whose synthesized fallback size would exceed the
        largest configured bucket — is served immediately as a direct
        un-coalesced protocol call (stats.direct). Keyword overrides (num_servers,
        mode, method, recover, standby, straggler_deadline, dtype,
        transport) place the request in a bucket matching that
        security/precision/execution config — an f32 client never shares
        a compiled sweep with f64 clients, and an inline sweep never
        coalesces with a multiprocess one.
        """
        unknown = set(overrides) - _OVERRIDE_KEYS
        if unknown:
            # a misspelled security override must fail loudly — silently
            # serving under the gateway defaults would hand the client a
            # weaker config than it asked for
            raise TypeError(
                f"unknown submit() overrides {sorted(unknown)}; "
                f"allowed: {sorted(_OVERRIDE_KEYS)}"
            )
        matrix = np.asarray(matrix)
        if matrix.ndim != 2 or matrix.shape[0] != matrix.shape[1]:
            raise ValueError(f"expected one square matrix, got {matrix.shape}")
        n = int(matrix.shape[0])
        if n < 2:
            raise ValueError("matrices must be at least 2x2 (KeyGen needs "
                             "n >= 2 blinding elements)")
        if not np.all(np.isfinite(matrix)):
            raise ValueError("matrix contains non-finite entries")
        now = self._clock() if now is None else now
        with self._lock:
            rid = self._next_rid
            self._next_rid += 1
            self.stats.submitted += 1
            req = DetRequest(rid=rid, matrix=matrix, n=n, enqueued_at=now)
            try:
                key = self._key_for(n, overrides)
            except NoBucketFits:
                key = None
            if key is not None:
                try:
                    full = self._queue.push(key, req)
                except GatewayOverloaded:
                    self.stats.submitted -= 1
                    self.stats.rejected += 1
                    raise
        if key is None:
            self._run_direct(req, overrides, now)
        elif full and self._auto_flush:
            self._flush(key, "full", now)
        return rid

    # -- flushing -----------------------------------------------------------

    def poll(self, now: float | None = None) -> list[GatewayResult]:
        """Flush every due bucket (full, or past the wait budget) and
        return the newly completed results."""
        now = self._clock() if now is None else now
        out: list[GatewayResult] = []
        while True:
            with self._lock:
                due = self._queue.due(now)
            if not due:
                return out
            for key, reason in due:
                out.extend(self._flush(key, reason, now))

    def drain(self) -> list[GatewayResult]:
        """Flush every bucket regardless of policy (shutdown / test sync),
        still in max_batch chunks so sweeps reuse warm shapes."""
        now = self._clock()
        out: list[GatewayResult] = []
        while True:
            with self._lock:
                keys = self._queue.keys()
            if not keys:
                return out
            for key in keys:
                out.extend(self._flush(key, "drain", now))

    def next_deadline(self, now: float | None = None) -> float | None:
        """Seconds until the earliest pending flush deadline (the async
        flusher's sleep bound); None when no requests are queued."""
        now = self._clock() if now is None else now
        with self._lock:
            return self._queue.next_deadline(now)

    def has_full_bucket(self) -> bool:
        with self._lock:
            return self._queue.has_full()

    @property
    def pending(self) -> int:
        return self._queue.pending

    def take(self, rid: int) -> GatewayResult | None:
        """Claim a completed result (None while its bucket is pending)."""
        with self._lock:
            return self._results.pop(rid, None)

    def _flush(self, key: BucketKey, reason: str, now: float):
        with self._lock:
            reqs = self._queue.pop(key, limit=self.config.max_batch)
            if not reqs:
                return []
            self.stats.flushes += 1
            if reason == "full":
                self.stats.flushes_full += 1
            elif reason == "timeout":
                self.stats.flushes_timeout += 1
            else:
                self.stats.flushes_drain += 1
        mats = [r.matrix for r in reqs]
        if self.config.pad_batches:
            target = next(
                b for b in allowed_batch_sizes(self.config.max_batch)
                if b >= len(mats)
            )
            mats = mats + [
                self._dummy(key.pad_to) for _ in range(target - len(mats))
            ]
        try:
            faults = self._faults_for(key) if self._faults_for else None
            res = outsource_determinant_mixed(
                mats,
                key.num_servers,
                faults=faults,
                **key.protocol_kwargs(),
            )
        except Exception as e:  # noqa: BLE001 — fail the requests, not the service
            # the bucket is already popped: every co-batched request gets
            # its own failed result instead of vanishing (and the async
            # flusher keeps running)
            return self._fail_requests(reqs, key, reason, f"{type(e).__name__}: {e}")
        done = self._clock()
        out = []
        with self._lock:
            if res.report.recovery is not None:
                self.stats.recovered_flushes += 1
            for i, req in enumerate(reqs):
                gres = GatewayResult(
                    rid=req.rid,
                    det=res.dets[i],
                    verified=bool(res.verified[i]),
                    residual=float(res.residual[i]),
                    n=req.n,
                    pad_to=key.pad_to,
                    batch=len(reqs),
                    flush_reason=reason,
                    submitted_at=req.enqueued_at,
                    completed_at=done,
                    recovery=res.report.recovery,
                )
                self._results[req.rid] = gres
                out.append(gres)
                self.stats.served += 1
        return out

    def _fail_requests(self, reqs, key: BucketKey, reason: str, error: str):
        """Deliver a per-request failure result for a sweep that raised."""
        done = self._clock()
        out = []
        with self._lock:
            self.stats.failed += len(reqs)
            for req in reqs:
                gres = GatewayResult(
                    rid=req.rid,
                    det=None,
                    verified=False,
                    residual=float("nan"),
                    n=req.n,
                    pad_to=key.pad_to,
                    batch=len(reqs),
                    flush_reason=reason,
                    submitted_at=req.enqueued_at,
                    completed_at=done,
                    error=error,
                )
                self._results[req.rid] = gres
                out.append(gres)
        return out

    def _run_direct(self, req: DetRequest, overrides: dict, now: float):
        """Oversize escape hatch: one un-coalesced protocol call."""
        from repro.core.protocol import outsource_determinant

        spdc = self.config.spdc
        try:
            res = outsource_determinant(
                req.matrix,
                overrides.get("num_servers", spdc.num_servers),
                mode=overrides.get("mode", spdc.mode),
                method=overrides.get("method", spdc.method),
                lambda1=overrides.get("lambda1", spdc.lambda1),
                lambda2=overrides.get("lambda2", spdc.lambda2),
                recover=overrides.get("recover", spdc.recover),
                standby=overrides.get("standby", spdc.standby),
                straggler_deadline=overrides.get(
                    "straggler_deadline", spdc.straggler_deadline
                ),
                dtype=overrides.get("dtype", spdc.dtype),
                growth_safe=overrides.get("growth_safe", spdc.growth_safe),
                equilibrate=overrides.get("equilibrate", spdc.equilibrate),
                transport=self._resolve_transport(
                    overrides.get("transport", spdc.transport)
                ),
                rateless=overrides.get("rateless", spdc.rateless),
            )
        except Exception as e:  # noqa: BLE001 — fail the request, not the service
            key = BucketKey(pad_to=req.n, num_servers=spdc.num_servers,
                            rateless=spdc.rateless)
            self._fail_requests([req], key, "direct",
                                f"{type(e).__name__}: {e}")
            return
        with self._lock:
            self.stats.direct += 1
            self._results[req.rid] = GatewayResult(
                rid=req.rid,
                det=res.det,
                verified=res.verified,
                residual=res.residual,
                n=req.n,
                pad_to=req.n + res.padding,
                batch=1,
                flush_reason="direct",
                submitted_at=req.enqueued_at,
                completed_at=self._clock(),
                recovery=res.report.recovery,
            )

    def _dummy(self, n_bucket: int) -> np.ndarray:
        """Client-profile filler matrix for batch padding: diag-dominant
        noise, cached per bucket. (A bare scaled identity would rotate to
        an exactly singular anti-diagonal under the cipher's PRT stage —
        fillers must look like real client matrices.) Its result is
        discarded; it exists so the sweep runs at a warmed batch shape."""
        cached = getattr(self, "_dummies", None)
        if cached is None:
            cached = self._dummies = {}
        if n_bucket not in cached:
            rng = np.random.default_rng(n_bucket)
            cached[n_bucket] = (
                rng.standard_normal((n_bucket, n_bucket))
                + n_bucket * np.eye(n_bucket)
            )
        return cached[n_bucket]

    # -- warmup -------------------------------------------------------------

    def warmup(self, batch_sizes: tuple[int, ...] | None = None) -> int:
        """Pre-compile each bucket's sweep at the given batch sizes.

        The coalesced sweep jit-compiles per (B, n', N, fault-plan) shape;
        a cold bucket's first flush would otherwise pay seconds of XLA
        compilation in a client's latency. The default shape set is
        exactly what pad_batches can produce (allowed_batch_sizes), so a
        warmed gateway never compiles during a flush. Returns the number
        of programs compiled. Runs the protocol sweep directly on
        well-conditioned dummy matrices — results are discarded and the
        serving queue/stats are never touched.
        """
        sizes = batch_sizes or self.config.warmup_batches
        if not sizes:
            sizes = (
                allowed_batch_sizes(self.config.max_batch)
                if self.config.pad_batches
                else (self.config.max_batch,)
            )
        spdc = self.config.spdc
        compiled = 0
        # every configured bucket is servable — __init__ validates the
        # preset against spdc.num_servers and raises otherwise
        for n_bucket in self.config.buckets:
            for b in sizes:
                # the same cached filler live batch padding uses, so warmup
                # compiles against the exact matrix profile flushes see
                dummies = [self._dummy(n_bucket)] * b
                key = self._key_for(n_bucket, {})
                res = outsource_determinant_mixed(
                    dummies, key.num_servers, **key.protocol_kwargs()
                )
                assert bool(np.all(res.verified))
                compiled += 1
        return compiled


class AsyncSPDCGateway:
    """asyncio front-end: ``await submit(m)`` → GatewayResult.

    A background flusher task wakes on the earliest flush deadline (or
    immediately when a bucket fills) and runs the device sweep in a worker
    thread, so the event loop keeps accepting submissions while the
    servers factor the previous batch. Use as an async context manager:

        async with AsyncSPDCGateway(cfg) as gw:
            results = await asyncio.gather(*(gw.submit(m) for m in ms))
    """

    def __init__(self, config: SPDCGatewayConfig = SPDC_GATEWAY_DEFAULT,
                 **kwargs):
        kwargs.setdefault("auto_flush", False)
        self._gw = SPDCGateway(config, **kwargs)
        self._waiters: dict[int, object] = {}
        self._task = None
        self._kick = None
        self._closed = False

    @property
    def stats(self) -> GatewayStats:
        return self._gw.stats

    @property
    def pending(self) -> int:
        return self._gw.pending

    async def __aenter__(self):
        import asyncio

        self._kick = asyncio.Event()
        self._task = asyncio.create_task(self._flusher())
        return self

    async def __aexit__(self, *exc):
        await self.aclose()

    async def aclose(self):
        import asyncio

        self._closed = True
        if self._task is not None:
            self._kick.set()
            await self._task
            self._task = None
        if self._gw.pending:
            await asyncio.to_thread(self._gw.drain)
            self._deliver()
        # release owned transports (worker pools, socket daemons) after
        # the final drain so shutdown is deterministic, not GC-timed
        await asyncio.to_thread(self._gw.close)

    async def warmup(self, batch_sizes: tuple[int, ...] | None = None) -> int:
        """Pre-compile bucket sweeps off the event loop (SPDCGateway.warmup)."""
        import asyncio

        return await asyncio.to_thread(self._gw.warmup, batch_sizes)

    async def submit(self, matrix, **overrides) -> GatewayResult:
        """Enqueue one matrix and wait for its bucket's sweep.

        Raises GatewayOverloaded immediately (without queueing) when the
        gateway is backpressured.
        """
        import asyncio

        if self._task is None:
            raise RuntimeError("use `async with AsyncSPDCGateway(...)`")
        # to_thread keeps the event loop free even when submit() itself
        # does device work (the oversize direct-call escape hatch)
        rid = await asyncio.to_thread(self._gw.submit, matrix, **overrides)
        ready = self._gw.take(rid)
        if ready is not None:  # oversize direct call completed inline
            return ready
        fut = asyncio.get_running_loop().create_future()
        self._waiters[rid] = fut
        self._kick.set()
        if self._closed:
            # aclose() may have drained before our enqueue landed (its
            # pending check raced our to_thread); flush ourselves so this
            # future cannot be stranded
            await asyncio.to_thread(self._gw.drain)
            self._deliver()
        return await fut

    def _deliver(self):
        for rid in list(self._waiters):
            res = self._gw.take(rid)
            if res is None:
                continue
            fut = self._waiters.pop(rid)
            if not fut.done():
                fut.set_result(res)

    async def _flusher(self):
        import asyncio

        while not self._closed:
            deadline = self._gw.next_deadline()
            if not self._gw.has_full_bucket():
                timeout = deadline if deadline is not None else 0.5
                try:
                    await asyncio.wait_for(
                        self._kick.wait(), timeout=max(timeout, 1e-4)
                    )
                except asyncio.TimeoutError:
                    pass
                self._kick.clear()
                if self._closed:
                    break
            if self._gw.pending:
                # _flush already converts sweep failures into per-request
                # error results; anything else must not kill the flusher
                # (every later submission would hang on a dead task)
                try:
                    await asyncio.to_thread(self._gw.poll)
                except Exception:  # noqa: BLE001
                    pass
                self._deliver()
