"""Decode caches: ring-buffered KV for attention, recurrent state for SSM.

Per-layer cache length is *pattern-aware* — the production memory story for
the long-context archs:

  * full-attention layers  → max_seq slots
  * sliding-window layers  → `window` slots (ring buffer; stale slots are
    masked by their stored absolute positions, so no shifting ever happens)
  * chunked layers         → `window` (= chunk) slots, same ring mechanics
  * ssm layers             → O(1): (B, H, N, P) state + 3-step conv tail

At jamba's long_500k cell this is the difference between 9 attention layers
holding 500k KV (19 GB total) and 72 layers doing so (155 GB).

Cache k/v length is sharded over the model axis (flash-decoding style):
every arch divides 16 evenly in the seq dim, unlike kv-heads (8 < 16), and
attention over a seq-sharded cache partitions into per-shard partial
softmaxes combined by the SPMD partitioner.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.blocks import split_layers
from repro.models.ssm import init_ssm_cache


def layer_cache_len(cfg, mixer: str, max_seq: int) -> int:
    if mixer == "attn_full":
        return max_seq
    return min(cfg.window or max_seq, max_seq)


def init_layer_cache(cfg, mixer: str, batch: int, max_seq: int):
    if mixer == "ssm":
        return init_ssm_cache(cfg, batch, cfg.dtype)
    length = layer_cache_len(cfg, mixer, max_seq)
    hk, dh = cfg.num_kv_heads, cfg.head_dim
    return {
        "k": jnp.zeros((batch, length, hk, dh), cfg.dtype),
        "v": jnp.zeros((batch, length, hk, dh), cfg.dtype),
        "pos": jnp.full((length,), -1, jnp.int32),
        "step": jnp.zeros((), jnp.int32),
    }


def init_caches(cfg, batch: int, max_seq: int) -> dict:
    """Cache tree mirroring the param stack ({"periods": stacked, ...})."""
    n_periods, rem = split_layers(cfg)

    def one_period():
        return {
            f"l{i}": init_layer_cache(cfg, mixer, batch, max_seq)
            for i, (mixer, _) in enumerate(cfg.pattern)
        }

    periods = [one_period() for _ in range(n_periods)]
    out = {"periods": jax.tree.map(lambda *xs: jnp.stack(xs), *periods)}
    if rem:
        out["remainder"] = {
            f"l{i}": init_layer_cache(cfg, cfg.pattern[i][0], batch, max_seq)
            for i in range(rem)
        }
    return out


def cache_logical_specs(cfg, cache_tree) -> dict:
    """Logical PartitionSpec names per cache leaf (resolved by rules)."""

    def spec_for(path, leaf):
        names = [p.key for p in path if hasattr(p, "key")]
        leading = ("periods" in [str(getattr(p, "key", "")) for p in path])
        base: tuple
        last = names[-1] if names else ""
        if last in ("k", "v"):
            base = ("batch", "model", None, None)
        elif last == "pos":
            base = ("model",)
        elif last == "step":
            base = ()
        elif last == "state":
            base = ("batch", "model", None, None)
        elif last == "conv":
            base = ("batch", None, None)
        else:
            base = tuple(None for _ in leaf.shape)
        if leading and len(base) < len(leaf.shape):
            base = (None,) + base
        return base

    from repro.compat import tree_map_with_path

    return tree_map_with_path(spec_for, cache_tree)


def merge_cache_updates(old: dict, upd: dict) -> dict:
    """Fold per-layer decode deltas into the cache tree.

    Attention layers emit {k_new, v_new, pos_new} (see models/attention.py —
    the write is deferred out of the period scan so XLA cannot materialize
    f32 copies of the stacked buffers); SSM layers emit full replacement
    states. Stacked (per-period) and unstacked (remainder) layers both
    supported; the ring index comes from the layer's own step counter.
    """
    import jax.numpy as jnp
    from jax import lax

    def merge_layer(o: dict, u: dict) -> dict:
        if "state" in u:  # ssm: full replacement
            return u
        cl = o["k"].shape[-3]
        step0 = o["step"].reshape(-1)[0]
        idx = (step0 % cl).astype(jnp.int32)
        z = jnp.zeros((), jnp.int32)
        if o["k"].ndim == 5:  # stacked over periods
            starts4 = (z, z, idx, z, z)
            pstarts = (z, idx)
        else:
            starts4 = (z, idx, z, z)
            pstarts = (idx,)
        # pos_new arrives as (1,) unstacked or (P, 1) stacked — exactly the
        # update-slice shape for pos of (L,) / (P, L)
        return {
            "k": lax.dynamic_update_slice(o["k"], u["k_new"], starts4),
            "v": lax.dynamic_update_slice(o["v"], u["v_new"], starts4),
            "pos": lax.dynamic_update_slice(o["pos"], u["pos_new"], pstarts),
            "step": o["step"] + 1,
        }

    out = {}
    for section in old:
        out[section] = {
            name: merge_layer(old[section][name], upd[section][name])
            for name in old[section]
        }
    return out


def cache_bytes(cfg, batch: int, max_seq: int) -> int:
    tree = jax.eval_shape(lambda: init_caches(cfg, batch, max_seq))
    return sum(x.size * x.dtype.itemsize for x in jax.tree.leaves(tree))
