"""Micro-batch request queue for the SPDC edge gateway (DESIGN.md §5).

The paper's deployment story is a stream of resource-constrained IoT
clients each outsourcing ONE determinant at a time, while the repo's
throughput lever (DESIGN.md §3) is the batched protocol sweep. This module
is the piece between them: it holds in-flight single-matrix requests,
groups them into *buckets* that can legally share one coalesced sweep, and
decides when a bucket is ripe to flush.

Bucketing rule: two requests may share a sweep iff they agree on every
protocol parameter the sweep compiles against — the padded size n' and the
full security config (server count, cipher mode, verification method,
recovery policy). That tuple is the `BucketKey`; it doubles as the jit
compile-cache key, so a warm gateway re-runs the same compiled program for
every flush of a bucket.

Flush policy (the gateway's latency/throughput dial):
  * max_batch   — a full bucket flushes immediately (throughput bound);
  * max_wait_us — a partial bucket flushes once its oldest request has
                  waited this long (latency bound under light traffic);
  * max_pending — total queued requests beyond this raise
                  `GatewayOverloaded` at submit time (backpressure: shed
                  load at the door instead of growing an unbounded queue).

Pure bookkeeping — no jax, no clocks. The gateway injects `now` so tests
drive flush timing deterministically with a virtual clock.
"""

from __future__ import annotations

from collections import OrderedDict
from dataclasses import dataclass, field


class GatewayOverloaded(RuntimeError):
    """Backpressure rejection: the gateway's pending queue is full.

    Raised at submit time — the paper's edge clients are latency-bound, so
    shedding a request immediately (letting the client retry against
    another gateway) beats queueing it behind more work than the servers
    can drain.
    """


class NoBucketFits(ValueError):
    """The request's matrix is larger than every configured bucket size
    (the gateway then serves it as a direct un-coalesced call)."""


@dataclass(frozen=True)
class BucketKey:
    """Everything a coalesced sweep compiles against: the shared padded
    size and the complete security configuration. Hashable — used both as
    the queue index and (via the protocol's static jit arguments) the
    compile-cache identity of the bucket's device program."""

    pad_to: int
    num_servers: int
    #: which secure-linalg operation this bucket serves (DESIGN.md §12).
    #: Part of the key: "det" and "slogdet" sweeps coalesce per-op (they
    #: read the same Determinant differently but must report distinct
    #: metrics series), and "solve" requests carry an RHS payload that the
    #: batched determinant sweep has no lane for — they run per-request
    #: LinalgSessions instead. Same transport instance across ops ⇒ the
    #: buckets still share one warm worker pool.
    op: str = "det"
    mode: str = "ewd"
    method: str = "q3"
    lambda1: int = 128
    lambda2: int = 128
    recover: bool = False
    standby: int = 0
    straggler_deadline: int | None = None
    #: compute dtype of the bucket's sweep. Part of the key so float32 and
    #: float64 clients never share a compiled program, a warmup cache, or
    #: an ε(N) calibration — a coalesced sweep has ONE device dtype.
    dtype: str = "float64"
    #: growth-control overrides (DESIGN.md §6; None = the protocol's
    #: dtype-keyed auto rule). Part of the key: they change the compiled
    #: sweep AND the factor values, so explicit settings cannot share a
    #: bucket with auto-ruled requests.
    growth_safe: bool | None = None
    equilibrate: bool | None = None
    #: execution boundary of the bucket's sweeps (DESIGN.md §7/§9). Part
    #: of the key: an inline sweep and a multiprocess sweep are different
    #: programs with different warm state, so requests targeting different
    #: transports must not coalesce. A name ("inline" | "threadpool" |
    #: "multiprocess" | "socket" | "shardmap") or a live Transport
    #: instance (hashed by identity; the gateway resolves TransportConfig
    #: overrides to its owned instances BEFORE keying, so equal configs
    #: land in one bucket and share one warm pool).
    transport: object = "inline"
    #: rateless dispatch (DESIGN.md §8). Part of the key: a rateless sweep
    #: partitions the bucket into F = overdecompose·N strips instead of N,
    #: so its padded size rides a different grid and its session carries
    #: fleet-health state a deadline-based sweep has no use for.
    rateless: bool = False

    def label(self) -> str:
        """Stable human-readable metrics label for this bucket.

        Leads with the fields operators actually scan for (size, fleet,
        dtype, method) and appends a short digest of the full key so two
        buckets differing only in a rarely-varied field (lambda1, a
        transport instance) never silently merge their metrics series.
        """
        import zlib

        core = (f"n{self.pad_to}.N{self.num_servers}.{self.dtype}"
                f".{self.mode}-{self.method}")
        if self.op != "det":
            core += f".{self.op}"
        if self.rateless:
            core += ".rateless"
        rest = (self.lambda1, self.lambda2, self.recover, self.standby,
                self.straggler_deadline, self.growth_safe, self.equilibrate,
                str(self.transport) if isinstance(self.transport, str)
                else f"transport@{id(self.transport):x}")
        return f"{core}#{zlib.crc32(repr(rest).encode()) & 0xFFFF:04x}"

    def protocol_kwargs(self) -> dict:
        """Keyword arguments for core.protocol.outsource_determinant_mixed.

        `op` is deliberately absent: it selects WHICH engine a flush runs
        (the batched determinant sweep vs per-request LinalgSessions), not
        a parameter of the sweep itself.
        """
        return dict(
            pad_to=self.pad_to,
            mode=self.mode,
            method=self.method,
            lambda1=self.lambda1,
            lambda2=self.lambda2,
            recover=self.recover,
            standby=self.standby,
            straggler_deadline=self.straggler_deadline,
            dtype=self.dtype,
            growth_safe=self.growth_safe,
            equilibrate=self.equilibrate,
            transport=self.transport,
            rateless=self.rateless,
        )

    def linalg_kwargs(self) -> dict:
        """Keyword arguments for linalg.LinalgSession (op="solve" flushes).

        The session has no equilibrate / straggler_deadline / rateless
        knobs (it forces equilibration off so the LU factors stay exactly
        reusable, and solve rounds are narrow enough that deadline and
        rateless dispatch buy nothing), so those BucketKey fields are
        dropped rather than forwarded. A "q3" method is promoted to "q2":
        Q3's diagonal-only residual cannot DRIVE recovery of in-band
        relay poisoning on factors that will be reused (linalg.session
        runs an explicit Q3 post-check on the accepted factors either
        way), so the secret-probed full-product check is the one the
        session's healing loop must steer by.
        """
        return dict(
            transport=self.transport,
            mode=self.mode,
            method="q2" if self.method == "q3" else self.method,
            lambda1=self.lambda1,
            lambda2=self.lambda2,
            recover=self.recover,
            standby=self.standby,
            dtype=self.dtype,
            growth_safe=self.growth_safe,
        )


@dataclass
class DetRequest:
    """One client request: a single square matrix awaiting a verdict."""

    rid: int
    matrix: object  # (n, n) ndarray — kept framework-agnostic here
    n: int
    enqueued_at: float
    #: admission-accounting dimension (DESIGN.md §10.1) — NOT part of the
    #: BucketKey: tenants coalesce into shared sweeps, only their quota
    #: bookkeeping is separate
    tenant: str = "default"
    #: idempotency cache key (BucketKey, tenant, content digest) the
    #: gateway resolved at submit time; None when caching is off or the
    #: request rides the direct path
    ckey: object = None
    #: which secure-linalg op the client asked for ("det" | "slogdet" |
    #: "solve"); mirrors the request's BucketKey.op for the direct path
    op: str = "det"
    #: right-hand side for op="solve" — an (n,) or (n, c) ndarray; None
    #: for determinant-family requests
    rhs: object = None


#: Granularity of synthesized fallback buckets: sizes are rounded up to
#: the next multiple of num_servers * SYNTH_GRID. Synthesizing the exact
#: smallest servable n' per request would open one bucket — one jitted
#: sweep plus warmup — per distinct request size, silently unbounding the
#: gateway's compile set under a diverse (or adversarial) size
#: distribution. The grid caps the synthesized-bucket count at
#: ~max(buckets)/(N·SYNTH_GRID) at the price of up to N·SYNTH_GRID − 1
#: extra padding rows (identity-extension rows are protocol-exact, so the
#: cost is compute only, and it is largest in relative terms exactly where
#: matrices are cheapest).
SYNTH_GRID = 16


def bucket_size_for(n: int, buckets: tuple[int, ...], num_servers: int) -> int:
    """Smallest configured bucket that can serve an (n, n) request.

    A bucket n' is eligible when n' >= n and the N-server schedule accepts
    it (n' % N == 0, n'/N > 1 — paper §IV.D.1).

    When a large-enough bucket exists but EVERY one fails the divisibility
    test (e.g. the default {64..1024} power-of-two buckets with a
    num_servers=3 override), a valid padded size still exists — a fallback
    bucket is synthesized on a coarse grid (next multiple of
    num_servers·SYNTH_GRID ≥ n, always servable: divisible by N with
    n'/N ≥ SYNTH_GRID > 1), so such requests keep coalescing with each
    other instead of erroring while the set of synthesized bucket sizes
    stays bounded (see SYNTH_GRID). (The pre-fix behavior raised
    NoBucketFits, silently demoting every such request to the un-coalesced
    direct path.) A synthesized size never exceeds max(buckets) — the
    operator's configured size cap bounds every coalesced sweep, so a
    request whose grid round-up would overshoot it falls to the direct
    path like any oversize request.

    Raises NoBucketFits when the matrix exceeds every configured bucket,
    or when the synthesized grid size would — both land on the gateway's
    direct un-coalesced call.
    """
    eligible = [b for b in buckets if b >= n]
    for b in sorted(eligible):
        if b % num_servers == 0 and b // num_servers > 1:
            return b
    if not eligible:
        raise NoBucketFits(
            f"no bucket in {sorted(buckets)} fits n={n} with N={num_servers}"
        )
    step = num_servers * SYNTH_GRID
    synth = ((n + step - 1) // step) * step
    if synth > max(buckets):
        raise NoBucketFits(
            f"synthesized fallback n'={synth} (grid N·{SYNTH_GRID}) exceeds "
            f"the largest configured bucket {max(buckets)} for n={n} with "
            f"N={num_servers}"
        )
    return synth


@dataclass
class _Bucket:
    requests: list[DetRequest] = field(default_factory=list)

    @property
    def oldest_at(self) -> float:
        return self.requests[0].enqueued_at

    def __len__(self) -> int:
        return len(self.requests)


@dataclass
class GatewayStats:
    """Operational counters; surfaced by the CLI driver and benchmarks."""

    submitted: int = 0
    rejected: int = 0  # backpressure at submit time (GatewayOverloaded)
    rejected_admission: int = 0  # per-tenant rate/quota (AdmissionRejected)
    rejected_breaker: int = 0  # bucket breaker open, fast-fail (BreakerOpen)
    direct: int = 0  # oversize requests served un-coalesced
    degraded_direct: int = 0  # breaker-open requests detoured direct
    served: int = 0  # requests answered through a coalesced flush
    failed: int = 0  # requests whose sweep raised (per-request error result)
    cache_hits: int = 0  # idempotency-cache hits (answered in O(hash))
    cache_misses: int = 0  # cache lookups that went on to enqueue
    coalesced: int = 0  # single-flight followers riding a leader's sweep
    breaker_opens: int = 0  # closed/half-open -> open transitions
    breaker_probes: int = 0  # half-open probe requests admitted
    breaker_closes: int = 0  # half-open -> closed recoveries
    flushes: int = 0
    flushes_full: int = 0  # max_batch reached
    flushes_timeout: int = 0  # max_wait_us exceeded on a partial bucket
    flushes_drain: int = 0  # explicit drain()
    recovered_flushes: int = 0  # flushes whose verdict needed re-dispatch

    def as_dict(self) -> dict:
        return dict(self.__dict__)


class MicroBatchQueue:
    """Pending requests, grouped by BucketKey, FIFO within a bucket."""

    def __init__(self, *, max_batch: int, max_wait_us: float,
                 max_pending: int):
        if max_batch < 1:
            raise ValueError("max_batch must be >= 1")
        self.max_batch = int(max_batch)
        self.max_wait_us = float(max_wait_us)
        self.max_pending = int(max_pending)
        # the queue has no lock of its own: every caller is the gateway,
        # already inside its RLock (enforced there via the gateway's own
        # guarded `_queue` reference — see tools/repro_lint, DESIGN.md §11)
        #: guarded-by: external(SPDCGateway._lock)
        self._buckets: "OrderedDict[BucketKey, _Bucket]" = OrderedDict()
        self._pending = 0  #: guarded-by: external(SPDCGateway._lock)

    @property
    def pending(self) -> int:
        return self._pending

    def push(self, key: BucketKey, req: DetRequest) -> bool:
        """Enqueue; returns True when the bucket just reached max_batch.

        Raises GatewayOverloaded when the gateway-wide pending total is at
        max_pending — the caller surfaces that to the client unserved.
        """
        if self._pending >= self.max_pending:
            raise GatewayOverloaded(
                f"{self._pending} requests pending (max_pending="
                f"{self.max_pending}); retry later"
            )
        bucket = self._buckets.setdefault(key, _Bucket())
        bucket.requests.append(req)
        self._pending += 1
        return len(bucket) >= self.max_batch

    def pop(self, key: BucketKey, limit: int | None = None) -> list[DetRequest]:
        """Remove and return up to `limit` of a bucket's requests (FIFO).

        The gateway flushes max_batch at a time even when a burst stacked
        more than that into one bucket — each sweep stays at the warmed-up
        (max_batch, n', n') shape instead of compiling a fresh program per
        burst size.
        """
        bucket = self._buckets.get(key)
        if bucket is None:
            return []
        if limit is None or len(bucket) <= limit:
            del self._buckets[key]
            taken = bucket.requests
        else:
            taken = bucket.requests[:limit]
            bucket.requests = bucket.requests[limit:]
        self._pending -= len(taken)
        return taken

    def due(self, now: float) -> list[tuple[BucketKey, str]]:
        """(bucket, reason) pairs ripe to flush at `now` — "full"
        (max_batch reached) or "timeout" (oldest request older than
        max_wait_us). Ordered oldest-bucket-first."""
        ready = []
        for key, bucket in self._buckets.items():
            if len(bucket) >= self.max_batch:
                ready.append((bucket.oldest_at, key, "full"))
            elif (now - bucket.oldest_at) * 1e6 >= self.max_wait_us:
                ready.append((bucket.oldest_at, key, "timeout"))
        ready.sort(key=lambda t: t[0])
        return [(k, reason) for _, k, reason in ready]

    def next_deadline(self, now: float) -> float | None:
        """Seconds until the earliest pending timeout flush (None when
        empty) — the async flusher's sleep bound."""
        if not self._buckets:
            return None
        oldest = min(b.oldest_at for b in self._buckets.values())
        return max(0.0, oldest + self.max_wait_us * 1e-6 - now)

    def has_full(self) -> bool:
        """True when some bucket already holds max_batch requests."""
        return any(len(b) >= self.max_batch for b in self._buckets.values())

    def keys(self) -> list[BucketKey]:
        return list(self._buckets)

    def depth_by_key(self) -> dict[BucketKey, int]:
        """Live per-bucket queue depth (the metrics depth gauge)."""
        return {k: len(b) for k, b in self._buckets.items()}
