"""LM-serving steps (seed model-zoo stack): prefill (parallel forward over
the prompt) and decode (one token against the caches). Factories mirror
train/steps.py.

NOTE: this is NOT the SPDC determinant service. The paper's workload is
served by the micro-batching gateway in `repro.serve.spdc_gateway`
(`python -m repro.launch.serve_spdc --help`, DESIGN.md §5).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.lm import forward_hidden, lm_logits_last


def build_prefill_step(cfg):
    """prefill_step(params, batch) -> last-position logits. batch carries
    tokens (B, S) (or stub embeds) for the full prompt."""

    def prefill_step(params, batch):
        hidden, _ = forward_hidden(params, batch, cfg, remat_policy="none")
        return lm_logits_last(params, hidden, cfg)

    return prefill_step


def build_decode_step(cfg):
    """decode_step(params, caches, inputs, pos) -> (logits, new_caches).

    inputs: {"tokens": (B, 1)} or {"embeds": (B, 1, D)}; pos: (B,) absolute
    position of this token (== number of tokens already in the cache).
    """

    def decode_step(params, caches, inputs, pos):
        b = pos.shape[0]
        positions = pos[:, None]
        if cfg.rope_type == "mrope":
            positions = jnp.repeat(positions[..., None], 3, axis=-1)
        hidden, new_caches = forward_hidden(
            params, inputs, cfg, positions=positions, caches=caches,
            remat_policy="none",
        )
        return lm_logits_last(params, hidden, cfg), new_caches

    return decode_step


def greedy_generate(cfg, params, prompt: jnp.ndarray, steps: int,
                    max_seq: int | None = None):
    """Example-grade generation: prefill via sequential decode (exactness
    over speed — production prefill threads K/V out of the parallel
    forward), then greedy decode. prompt: (B, S0)."""
    from .kvcache import init_caches

    b, s0 = prompt.shape
    max_seq = max_seq or (s0 + steps)
    caches = init_caches(cfg, b, max_seq)
    decode = jax.jit(build_decode_step(cfg))

    tok = prompt[:, :1]
    out = [tok]
    logits = None
    for t in range(s0 + steps - 1):
        pos = jnp.full((b,), t, jnp.int32)
        logits, caches = decode(params, caches, {"tokens": tok}, pos)
        if t + 1 < s0:
            tok = prompt[:, t + 1 : t + 2]  # teacher-forced prompt
        else:
            tok = jnp.argmax(logits, axis=-1)[:, None].astype(jnp.int32)
        out.append(tok)
    return jnp.concatenate(out, axis=1)
