"""Debug-mode lock-ownership assertions for the serving layer.

`repro-lint`'s lock pass (DESIGN.md §11) proves *lexically* that guarded
state is only touched under ``with self._lock``, but it cannot see through
dynamic dispatch or code the pass does not scan.  ``assert_owns_lock`` is
the runtime complement: drop it at the top of a mutation site and any
call path that reaches it without the lock fails loudly under ``python``
(the default, ``__debug__`` true) while compiling to a no-op under
``python -O`` — same contract as ``assert``.

Ownership detection is best-effort by lock flavor:

* ``threading.RLock`` — CPython's ``_is_owned()`` answers exactly
  "does *this* thread hold it".  This is the strong, preferred case and
  what every gateway lock uses.
* plain ``threading.Lock`` — not owner-tracked, so we probe with a
  non-blocking acquire: if the acquire *succeeds* the lock was free and
  the caller definitely did not hold it (we release and fail).  If it
  fails, *someone* holds it — possibly another thread — so we accept.
  One-sided, but it still catches the common bug of forgetting the
  ``with`` entirely in single-threaded tests.
"""
from __future__ import annotations

__all__ = ["assert_owns_lock"]


def _owns(lock) -> bool:
    is_owned = getattr(lock, "_is_owned", None)
    if is_owned is not None:  # RLock: exact per-thread answer
        return bool(is_owned())
    # Plain Lock: probe.  Acquiring means it was free => caller can't own it.
    if lock.acquire(blocking=False):
        lock.release()
        return False
    return True


def assert_owns_lock(lock, what: str = "guarded state") -> None:
    """Raise ``AssertionError`` if the calling thread does not hold *lock*.

    No-op under ``python -O`` (mirrors ``assert`` semantics), so hot
    paths may call it unconditionally.
    """
    if not __debug__:
        return
    if not _owns(lock):
        raise AssertionError(
            f"{what} touched without holding {lock!r}; wrap the call "
            "site in `with lock:` (see DESIGN.md §11)"
        )
