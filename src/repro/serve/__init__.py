"""Serving layer.

Two stacks live here:

* **SPDC gateway** (`queue`, `spdc_gateway`) — the paper's workload as a
  service: an async micro-batching determinant gateway that coalesces
  single-matrix client requests into batched protocol sweeps
  (DESIGN.md §5). Entry points: `SPDCGateway`, `AsyncSPDCGateway`,
  `python -m repro.launch.serve_spdc`.
* **LM serving substrate** (`kvcache`, `steps`) — KV/SSM caches and
  prefill/decode steps inherited from the seed's language-model stack;
  kept for the model-zoo scenarios (`python -m repro.launch.serve`).
"""

from .metrics import (  # noqa: F401
    FlushEvent,
    GatewayMetrics,
    MetricsSnapshot,
    QuantileSketch,
    RejectEvent,
    VerdictEvent,
    render_healthz,
    render_prometheus,
)
from .queue import (  # noqa: F401
    BucketKey,
    GatewayOverloaded,
    GatewayStats,
    MicroBatchQueue,
    NoBucketFits,
    bucket_size_for,
)
from .resilience import (  # noqa: F401
    AdmissionController,
    AdmissionRejected,
    BreakerOpen,
    CircuitBreaker,
    ResultCache,
    TokenBucket,
)
from .spdc_gateway import (  # noqa: F401
    AsyncSPDCGateway,
    GatewayResult,
    SPDCGateway,
)
