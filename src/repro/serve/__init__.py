"""Serving substrate: KV/SSM caches, prefill/decode steps."""
